//! The sharded parameter server: row- and feature-partitioned server
//! state with sparse histogram exchange (`ps_shards=N`).
//!
//! The single-`ServerCore` accept loop is a global serialization point:
//! every accepted tree runs its fused pass, target production and
//! publish on one thread, so accepted trees/sec plateaus once workers
//! outnumber what one server can absorb (the paper's Eq. 13 bound, and
//! the motivation Vasiloudis et al.'s *block-distributed GBT* gives for
//! partitioning the server — see PAPERS.md). This module partitions the
//! server state two ways:
//!
//! * **Rows** ([`RowPartition`]) — each of `ps_shards` server shards
//!   owns a contiguous, whole-[`ROW_BLOCK`] slice of **F**, the sampled
//!   weights and the grad/hess targets, and runs its slice of the fused
//!   accept pass ([`sharded_accept_pass`]) through the *same* per-shard
//!   kernel `ps/shard.rs` uses (`run_shard` is shared, not reimplemented).
//! * **Features** ([`FeaturePartition`]) — for histogram aggregation each
//!   shard owns a contiguous feature range, i.e. a contiguous global
//!   *slot* window of the flat histogram layout. Shards exchange only
//!   the **touched** bins of each window as [`SparseBins`] payloads
//!   (Vasiloudis et al.'s sparse-communication argument: on sparse data
//!   the touched fraction is small, so shard traffic is O(nnz), not
//!   O(features × bins)).
//!
//! Published snapshots compose per-shard versions ([`ShardVersions`]):
//! each shard bumps its own atomic version cell and the board-visible
//! version is the minimum across cells ([`compose_version`]) — readers
//! get a consistent versioned view without any global lock (the cells
//! are independent atomics; `fetch_max` keeps every cell monotone under
//! racing publishes).
//!
//! **Why `ps_shards` cannot change results, bit for bit:** the row
//! carving uses the *same* whole-block per/rem rule as the fused pass's
//! thread carving, and every per-row quantity (scored margin, keyed
//! Bernoulli draw, grad/hess) is a pure function of the row — so a row's
//! bits do not depend on which shard owns it. Eval partials are taken
//! per *global* block and folded in block order; sampled rows are
//! concatenated in ascending shard order. The only f64 caveat is the
//! histogram exchange: a slot's sum is grouped per *sender* shard, so
//! bin-for-bin equality with the dense whole-matrix build is exact when
//! the per-row values have exact f64 sums (the gradient-mode ±1/weight
//! targets used by the equivalence tests) and within rounding otherwise
//! — identical to the grouping already introduced by the tree builders'
//! fork-join histogram merge.
//!
//! **Transport seam:** shard ↔ shard messages go through
//! [`ShardTransport`], a two-method trait ([`ShardTransport::send`] /
//! [`ShardTransport::drain`]). [`LocalTransport`] is the in-process
//! mailbox implementation (mutexed inboxes, cross-shard bytes counted);
//! a multi-process PS replaces the transport, not the aggregation or
//! accept logic. Dispatch inside this module rides the server's
//! existing persistent [`Executor`] — shards may outnumber the thread
//! budget, in which case active workers claim shard tasks off a shared
//! counter instead of leaving shards unserved (`Executor::run` clamps
//! its `active` argument to the budget).

use std::ops::Range;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::data::BinnedDataset;
use crate::forest::score::{ScoreScratch, ScratchPool, ROW_BLOCK};
use crate::loss::logistic;
use crate::tree::histogram::Histogram;
use crate::util::Executor;

use super::messages::{HistShardMsg, SparseBins};
use super::shard::{run_shard, AcceptInputs, FusedResult, ShardTask};

/// Contiguous whole-[`ROW_BLOCK`] row ownership of the server shards.
///
/// The carving is the fused pass's per/rem rule: `n_blocks` blocks split
/// as evenly as possible, the first `n_blocks % n_shards` shards taking
/// one extra block, every boundary a block multiple (only the global
/// tail block may be short). Boundaries are a pure function of
/// `(n_rows, ps_shards)` — never of the data — which is the
/// shard-invariance property the test layer pins.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RowPartition {
    /// `n_shards + 1` ascending row boundaries; `starts[0] == 0`,
    /// `starts[n_shards] == n_rows`.
    starts: Vec<usize>,
}

impl RowPartition {
    /// Carve `n_rows` into at most `ps_shards` shards (clamped to the
    /// block count so no shard is empty; `ps_shards=1` is one shard
    /// owning everything — the single-server layout).
    pub fn new(n_rows: usize, ps_shards: usize) -> RowPartition {
        let n_blocks = n_rows.div_ceil(ROW_BLOCK).max(1);
        let n_shards = ps_shards.clamp(1, n_blocks);
        let per = n_blocks / n_shards;
        let rem = n_blocks % n_shards;
        let mut starts = Vec::with_capacity(n_shards + 1);
        starts.push(0usize);
        let mut row0 = 0usize;
        for s in 0..n_shards {
            let blocks = per + usize::from(s < rem);
            row0 += (blocks * ROW_BLOCK).min(n_rows - row0);
            starts.push(row0);
        }
        debug_assert_eq!(row0, n_rows);
        RowPartition { starts }
    }

    /// Number of shards actually carved (≤ the requested count).
    pub fn n_shards(&self) -> usize {
        self.starts.len() - 1
    }

    /// Total rows partitioned.
    pub fn n_rows(&self) -> usize {
        *self.starts.last().unwrap()
    }

    /// Shard `s`'s half-open row range.
    pub fn range(&self, shard: usize) -> Range<usize> {
        self.starts[shard]..self.starts[shard + 1]
    }

    /// Which shard owns global row `row`.
    pub fn shard_of_row(&self, row: usize) -> usize {
        debug_assert!(row < self.n_rows());
        self.starts.partition_point(|&b| b <= row) - 1
    }

    /// The raw boundary list (for the shard-invariance tests).
    pub fn boundaries(&self) -> &[usize] {
        &self.starts
    }
}

/// Contiguous feature ownership of the server shards for histogram
/// aggregation, aligned to the flat histogram layout: shard `s` owns the
/// features of `feature_range(s)` and therefore the global slot window
/// `slot_range(s)` (feature boundaries map to slot boundaries through
/// `BinnedDataset::offsets`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FeaturePartition {
    /// `n_shards + 1` ascending feature boundaries.
    feat_starts: Vec<usize>,
    /// The same boundaries as global slot ids (`offsets[feat_starts]`).
    slot_starts: Vec<usize>,
}

impl FeaturePartition {
    /// Carve the dataset's features into at most `ps_shards` contiguous
    /// ranges (same per/rem rule as [`RowPartition`], clamped so no
    /// shard is featureless).
    pub fn new(binned: &BinnedDataset, ps_shards: usize) -> FeaturePartition {
        let n_features = binned.n_features;
        let n_shards = ps_shards.clamp(1, n_features.max(1));
        let per = n_features / n_shards;
        let rem = n_features % n_shards;
        let mut feat_starts = Vec::with_capacity(n_shards + 1);
        feat_starts.push(0usize);
        let mut f0 = 0usize;
        for s in 0..n_shards {
            f0 += per + usize::from(s < rem);
            feat_starts.push(f0);
        }
        let slot_starts = feat_starts.iter().map(|&f| binned.offsets[f]).collect();
        FeaturePartition {
            feat_starts,
            slot_starts,
        }
    }

    /// Number of shards actually carved (≤ the requested count).
    pub fn n_shards(&self) -> usize {
        self.feat_starts.len() - 1
    }

    /// Shard `s`'s half-open feature range.
    pub fn feature_range(&self, shard: usize) -> Range<usize> {
        self.feat_starts[shard]..self.feat_starts[shard + 1]
    }

    /// Shard `s`'s half-open global slot window.
    pub fn slot_range(&self, shard: usize) -> Range<usize> {
        self.slot_starts[shard]..self.slot_starts[shard + 1]
    }

    /// Which shard owns global slot `slot`.
    pub fn owner_of_slot(&self, slot: usize) -> usize {
        debug_assert!(slot < *self.slot_starts.last().unwrap());
        self.slot_starts.partition_point(|&b| b <= slot) - 1
    }
}

/// Compose per-shard versions into the board-visible version: the
/// minimum — a snapshot is "at version v" only once *every* shard has
/// published v, so a reader composing the cells can never observe a
/// version no shard state backs yet. Empty input composes to 0.
pub fn compose_version(versions: &[u64]) -> u64 {
    versions.iter().copied().min().unwrap_or(0)
}

/// Per-shard version cells, each advanced independently (no global
/// lock): a shard publishes with `fetch_max`, so cells are monotone even
/// under racing publishes, and the composed view ([`compose_version`])
/// is monotone because a min of monotone sequences is monotone.
#[derive(Debug)]
pub struct ShardVersions {
    versions: Vec<AtomicU64>,
}

impl ShardVersions {
    /// `n_shards` cells, all at version 0 (at least one).
    pub fn new(n_shards: usize) -> ShardVersions {
        ShardVersions {
            versions: (0..n_shards.max(1)).map(|_| AtomicU64::new(0)).collect(),
        }
    }

    /// Number of cells.
    pub fn n_shards(&self) -> usize {
        self.versions.len()
    }

    /// Advance shard `s` to at least `version` (monotone: an older
    /// publish racing a newer one can never move a cell backwards).
    pub fn publish(&self, shard: usize, version: u64) {
        self.versions[shard].fetch_max(version, Ordering::AcqRel);
    }

    /// Shard `s`'s current version.
    pub fn shard_version(&self, shard: usize) -> u64 {
        self.versions[shard].load(Ordering::Acquire)
    }

    /// The composed (board-visible) version: min across cells.
    pub fn composed(&self) -> u64 {
        self.versions
            .iter()
            .map(|v| v.load(Ordering::Acquire))
            .min()
            .unwrap_or(0)
    }
}

/// The shard ↔ shard message fabric — the seam a multi-process PS
/// replaces. Implementations must deliver every sent message to exactly
/// one subsequent `drain(msg.to_shard)`; ordering across senders is NOT
/// required (receivers sort by sender, see [`aggregate_sharded`]).
pub trait ShardTransport: Sync {
    /// Enqueue one message for its destination shard.
    fn send(&self, msg: HistShardMsg);
    /// Take everything queued for `shard` (empties the inbox).
    fn drain(&self, shard: usize) -> Vec<HistShardMsg>;
}

/// In-process [`ShardTransport`]: one mutexed inbox per shard. Counts
/// the wire bytes of cross-shard payloads (self-sends are free — a real
/// deployment keeps them in memory) so benches and the simulator's cost
/// model can be validated against observed traffic.
#[derive(Debug)]
pub struct LocalTransport {
    inboxes: Vec<Mutex<Vec<HistShardMsg>>>,
    bytes: AtomicU64,
}

impl LocalTransport {
    /// A transport connecting `n_shards` shards (at least one).
    pub fn new(n_shards: usize) -> LocalTransport {
        LocalTransport {
            inboxes: (0..n_shards.max(1)).map(|_| Mutex::new(Vec::new())).collect(),
            bytes: AtomicU64::new(0),
        }
    }

    /// Total cross-shard payload bytes sent so far.
    pub fn bytes_sent(&self) -> u64 {
        self.bytes.load(Ordering::Relaxed)
    }
}

impl ShardTransport for LocalTransport {
    fn send(&self, msg: HistShardMsg) {
        if msg.from_shard != msg.to_shard {
            self.bytes
                .fetch_add(msg.bins.wire_bytes() as u64, Ordering::Relaxed);
        }
        self.inboxes[msg.to_shard].lock().unwrap().push(msg);
    }

    fn drain(&self, shard: usize) -> Vec<HistShardMsg> {
        std::mem::take(&mut *self.inboxes[shard].lock().unwrap())
    }
}

/// Sharded histogram aggregation: each row shard builds a local
/// histogram over its slice of `rows`, encodes the touched bins of every
/// destination's slot window as [`SparseBins`], and ships them through
/// the transport; each feature shard then merges what it received in
/// ascending sender order. Returns the assembled whole-matrix histogram
/// (slot windows are disjoint, so assembly is just every destination's
/// merge landing in one buffer; row totals fold once per sender).
///
/// Determinism: source builds run in parallel on `exec` (workers claim
/// sources off a shared counter), but sends happen afterwards in
/// ascending source order and receivers sort by `from_shard` before
/// merging — the result is a pure function of `(rows, partitions)`,
/// never of scheduling. Equals the dense `Histogram::build` over all of
/// `rows` bin-for-bin: exactly when per-slot f64 sums are exact (integer
/// -valued targets), within grouping rounding otherwise (module docs).
///
/// Retry safety: every payload is stamped with `epoch` (the caller's
/// aggregation round) and receivers keep at most one message per
/// `(from_shard, epoch)` — messages from other rounds are discarded and
/// same-round duplicates deduped before merging. A lossy transport (see
/// `ps::faulty::FaultyTransport`) may therefore retry, duplicate, or
/// replay sends without changing the merged histogram; on a clean
/// transport the filter is a no-op and the result is byte-identical to
/// the pre-epoch behavior (DESIGN.md §14).
#[allow(clippy::too_many_arguments)]
pub fn aggregate_sharded(
    binned: &BinnedDataset,
    rows: &[u32],
    grad: &[f32],
    hess: &[f32],
    rowp: &RowPartition,
    featp: &FeaturePartition,
    transport: &dyn ShardTransport,
    exec: &Executor,
    epoch: u64,
) -> Histogram {
    let n_src = rowp.n_shards();
    let n_dst = featp.n_shards();
    // `rows` is ascending, so each source's slice is one contiguous run
    let mut cuts = Vec::with_capacity(n_src + 1);
    cuts.push(0usize);
    for src in 0..n_src {
        let end = rowp.range(src).end;
        let prev = *cuts.last().unwrap();
        cuts.push(prev + rows[prev..].partition_point(|&r| (r as usize) < end));
    }
    // source phase (parallel, claimed off a counter): build the local
    // histogram, encode one payload per destination window — empty
    // payloads still ship, carrying the source's row totals
    let next = AtomicUsize::new(0);
    let batches: Vec<Mutex<Vec<HistShardMsg>>> =
        (0..n_src).map(|_| Mutex::new(Vec::new())).collect();
    let active = exec.threads().min(n_src).max(1);
    exec.run(active, &|_tid| {
        let mut local = Histogram::zeros(binned.total_bins());
        loop {
            let src = next.fetch_add(1, Ordering::Relaxed);
            if src >= n_src {
                break;
            }
            local.build(binned, &rows[cuts[src]..cuts[src + 1]], grad, hess);
            let msgs: Vec<HistShardMsg> = (0..n_dst)
                .map(|dst| HistShardMsg {
                    from_shard: src,
                    to_shard: dst,
                    bins: SparseBins::from_histogram(&local, featp.slot_range(dst)),
                    totals: local.totals,
                    epoch,
                })
                .collect();
            *batches[src].lock().unwrap() = msgs;
        }
    });
    for batch in batches {
        for msg in batch.into_inner().unwrap() {
            transport.send(msg);
        }
    }
    // destination phase: drain, keep only the current epoch at most once
    // per sender (the at-most-once contract — stale replays and retry
    // duplicates vanish here), order by sender, merge into the owned
    // window; totals fold once per sender (off destination 0's inbox,
    // which every sender addresses)
    let mut out = Histogram::zeros(binned.total_bins());
    for dst in 0..n_dst {
        let mut msgs = transport.drain(dst);
        msgs.retain(|m| m.epoch == epoch);
        msgs.sort_by_key(|m| m.from_shard);
        msgs.dedup_by_key(|m| m.from_shard);
        for m in &msgs {
            m.bins.apply_to(&mut out);
            if dst == 0 {
                out.totals.grad += m.totals.grad;
                out.totals.hess += m.totals.hess;
                out.totals.count += m.totals.count;
            }
        }
    }
    out
}

/// The sharded accept pass: [`super::shard::fused_accept_pass`]'s block
/// kernel run over a fixed [`RowPartition`] instead of a thread-count
/// carving — each server shard's owned slices go through the *same*
/// `run_shard` kernel, so the result is bit-identical to the fused pass
/// (and hence to the serial reference) for every shard count, executor
/// mode and thread budget. When shards outnumber `exec`'s threads,
/// active workers claim shard tasks off a shared counter
/// (`Executor::run` clamps its width, so naive one-task-per-index
/// dispatch would strand the excess shards).
pub fn sharded_accept_pass(
    inp: &AcceptInputs<'_>,
    f: &mut [f32],
    part: &RowPartition,
    exec: &Executor,
    pool: &mut ScratchPool,
) -> FusedResult {
    let n = f.len();
    assert_eq!(part.n_rows(), n, "partition does not cover F");
    assert_eq!(inp.y.len(), n);
    assert_eq!(inp.m.len(), n);
    assert_eq!(inp.sampler.n_rows(), n);
    let n_blocks = n.div_ceil(ROW_BLOCK).max(1);
    let n_shards = part.n_shards();
    let mut weights = vec![0.0f32; n];
    let target_len = if inp.compute_target { n } else { 0 };
    let mut grad = vec![0.0f32; target_len];
    let mut hess = vec![0.0f32; target_len];
    let mut eval_blocks =
        vec![(0.0f64, 0.0f64, 0.0f64); if inp.want_eval { n_blocks } else { 0 }];

    let rows = if n_shards == 1 {
        let mut scratch = pool.take();
        let rows = run_shard(
            inp,
            ShardTask {
                start_row: 0,
                f,
                weights: &mut weights,
                grad: &mut grad,
                hess: &mut hess,
                eval: &mut eval_blocks,
            },
            &mut scratch,
        );
        pool.give(scratch);
        rows
    } else {
        // carve disjoint &mut views at the partition's own boundaries
        // (whole blocks by construction, so per-shard eval slot counts
        // sum to the global block count)
        let mut tasks = Vec::with_capacity(n_shards);
        let mut f_rest = f;
        let mut w_rest = weights.as_mut_slice();
        let mut g_rest = grad.as_mut_slice();
        let mut h_rest = hess.as_mut_slice();
        let mut e_rest = eval_blocks.as_mut_slice();
        for s in 0..n_shards {
            let range = part.range(s);
            let len = range.len();
            let blocks = len.div_ceil(ROW_BLOCK);
            let (f_s, fr) = f_rest.split_at_mut(len);
            f_rest = fr;
            let (w_s, wr) = w_rest.split_at_mut(len);
            w_rest = wr;
            let target_len = if inp.compute_target { len } else { 0 };
            let (g_s, gr) = g_rest.split_at_mut(target_len);
            g_rest = gr;
            let (h_s, hr) = h_rest.split_at_mut(target_len);
            h_rest = hr;
            let (e_s, er) = e_rest.split_at_mut(if inp.want_eval { blocks } else { 0 });
            e_rest = er;
            tasks.push(ShardTask {
                start_row: range.start,
                f: f_s,
                weights: w_s,
                grad: g_s,
                hess: h_s,
                eval: e_s,
            });
        }
        let slots: Vec<Mutex<(Option<ShardTask<'_>>, ScoreScratch, Vec<u32>)>> = tasks
            .into_iter()
            .map(|task| Mutex::new((Some(task), pool.take(), Vec::new())))
            .collect();
        let next = AtomicUsize::new(0);
        let active = exec.threads().min(n_shards).max(1);
        exec.run(active, &|_tid| loop {
            let s = next.fetch_add(1, Ordering::Relaxed);
            if s >= n_shards {
                break;
            }
            let mut slot = slots[s].lock().unwrap();
            let (task, scratch, out) = &mut *slot;
            let task = task.take().expect("shard task dispatched twice");
            *out = run_shard(inp, task, scratch);
        });
        let parts: Vec<(ScoreScratch, Vec<u32>)> = slots
            .into_iter()
            .map(|slot| {
                let (_, scratch, shard_rows) = slot.into_inner().unwrap();
                (scratch, shard_rows)
            })
            .collect();
        let mut rows = Vec::with_capacity(parts.iter().map(|(_, r)| r.len()).sum());
        for (scratch, shard_rows) in parts {
            pool.give(scratch);
            rows.extend_from_slice(&shard_rows);
        }
        rows
    };

    let eval = inp
        .want_eval
        .then(|| logistic::fold_eval_blocks(&eval_blocks));
    FusedResult {
        weights,
        grad,
        hess,
        rows,
        eval,
    }
}

#[cfg(test)]
mod tests {
    use super::super::shard::fused_accept_pass;
    use super::*;
    use crate::data::{synthetic, Dataset};
    use crate::loss::ScalarLoss;
    use crate::sampling::{BernoulliSampler, SampleKey};
    use crate::tree::{build_tree, FlatTree, TreeParams};
    use crate::util::{PoolMode, Rng};
    use std::sync::Arc;

    #[test]
    fn row_partition_carves_whole_blocks_and_covers() {
        for (n_rows, shards) in [
            (10usize, 1usize),
            (10, 4),      // fewer blocks than shards: clamps to 1
            (5_000, 3),   // 10 blocks over 3 shards: 4/3/3
            (4_096, 8),   // exactly 8 blocks
            (4_100, 8),   // 9 blocks over 8 shards, short tail
            (100_000, 7),
        ] {
            let p = RowPartition::new(n_rows, shards);
            assert!(p.n_shards() >= 1 && p.n_shards() <= shards);
            assert_eq!(p.n_rows(), n_rows);
            let b = p.boundaries();
            assert_eq!(b[0], 0);
            assert_eq!(*b.last().unwrap(), n_rows);
            for w in b.windows(2) {
                assert!(w[0] < w[1], "empty shard in {b:?}");
            }
            // interior boundaries are block multiples (only the global
            // tail may be ragged)
            for &x in &b[1..p.n_shards()] {
                assert_eq!(x % ROW_BLOCK, 0, "boundary {x} not block-aligned");
            }
            // shard_of_row agrees with range() on every boundary's sides
            for s in 0..p.n_shards() {
                let r = p.range(s);
                assert_eq!(p.shard_of_row(r.start), s);
                assert_eq!(p.shard_of_row(r.end - 1), s);
            }
        }
        // blocks spread per/rem: first shards get the extra block
        let p = RowPartition::new(5_000, 3); // 10 blocks: 4, 3, 3
        assert_eq!(p.boundaries(), &[0, 4 * ROW_BLOCK, 7 * ROW_BLOCK, 5_000]);
    }

    #[test]
    fn row_partition_depends_only_on_count_and_shards() {
        // shard-invariance: boundaries are a pure function of the pair
        let a = RowPartition::new(9_999, 4);
        let b = RowPartition::new(9_999, 4);
        assert_eq!(a, b);
        assert_eq!(RowPartition::new(9_999, 1).boundaries(), &[0, 9_999]);
    }

    #[test]
    fn feature_partition_aligns_slot_windows_to_offsets() {
        let ds = synthetic::realsim_like(400, 11);
        let binned = BinnedDataset::from_dataset(&ds, 16).unwrap();
        for shards in [1usize, 2, 3, 64] {
            let p = FeaturePartition::new(&binned, shards);
            assert!(p.n_shards() >= 1 && p.n_shards() <= shards.max(1));
            // feature ranges tile [0, n_features); slot ranges tile
            // [0, total_bins) and land on feature boundaries
            let mut f_next = 0usize;
            let mut s_next = 0usize;
            for s in 0..p.n_shards() {
                let fr = p.feature_range(s);
                let sr = p.slot_range(s);
                assert_eq!(fr.start, f_next);
                assert_eq!(sr.start, s_next);
                assert_eq!(sr.start, binned.offsets[fr.start]);
                assert_eq!(sr.end, binned.offsets[fr.end]);
                for slot in sr.clone() {
                    assert_eq!(p.owner_of_slot(slot), s);
                }
                f_next = fr.end;
                s_next = sr.end;
            }
            assert_eq!(f_next, binned.n_features);
            assert_eq!(s_next, binned.total_bins());
        }
    }

    #[test]
    fn shard_versions_compose_to_the_minimum_and_stay_monotone() {
        assert_eq!(compose_version(&[]), 0);
        assert_eq!(compose_version(&[7]), 7);
        assert_eq!(compose_version(&[5, 3, 9]), 3);
        let v = ShardVersions::new(3);
        assert_eq!(v.composed(), 0);
        v.publish(0, 4);
        v.publish(1, 4);
        assert_eq!(v.composed(), 0, "shard 2 has not published yet");
        v.publish(2, 4);
        assert_eq!(v.composed(), 4);
        // a stale publish cannot move a cell backwards
        v.publish(1, 2);
        assert_eq!(v.shard_version(1), 4);
        assert_eq!(v.composed(), 4);
    }

    #[test]
    fn shard_versions_monotone_under_concurrent_publishes() {
        let v = Arc::new(ShardVersions::new(4));
        std::thread::scope(|s| {
            for shard in 0..4usize {
                let v = v.clone();
                s.spawn(move || {
                    for ver in 1..=500u64 {
                        v.publish(shard, ver);
                    }
                });
            }
            // a racing reader must see a non-decreasing composed view
            let v = v.clone();
            s.spawn(move || {
                let mut last = 0u64;
                for _ in 0..2_000 {
                    let c = v.composed();
                    assert!(c >= last, "composed went backwards: {c} < {last}");
                    last = c;
                }
            });
        });
        assert_eq!(v.composed(), 500);
    }

    #[test]
    fn local_transport_counts_only_cross_shard_bytes() {
        let t = LocalTransport::new(2);
        let mut h = Histogram::zeros(4);
        h.grad[1] = 1.0;
        h.hess[1] = 1.0;
        h.count[1] = 1;
        h.touched.push(1);
        let bins = SparseBins::from_histogram(&h, 0..4);
        t.send(HistShardMsg {
            from_shard: 0,
            to_shard: 0,
            bins: bins.clone(),
            totals: h.totals,
            epoch: 0,
        });
        assert_eq!(t.bytes_sent(), 0, "self-sends are free");
        t.send(HistShardMsg {
            from_shard: 0,
            to_shard: 1,
            bins: bins.clone(),
            totals: h.totals,
            epoch: 0,
        });
        assert_eq!(t.bytes_sent(), bins.wire_bytes() as u64);
        assert_eq!(t.drain(0).len(), 1);
        assert_eq!(t.drain(1).len(), 1);
        assert!(t.drain(1).is_empty(), "drain must empty the inbox");
    }

    #[test]
    fn sharded_aggregation_equals_dense_build_bin_for_bin() {
        // integer-valued targets (gradient mode's ±1 / unit weights) so
        // every per-slot f64 sum is exact and equality is bitwise
        let ds = synthetic::realsim_like(3_000, 31);
        let binned = Arc::new(BinnedDataset::from_dataset(&ds, 16).unwrap());
        let n = ds.n_rows();
        let grad: Vec<f32> = (0..n).map(|r| if r % 2 == 0 { 1.0 } else { -1.0 }).collect();
        let hess = vec![1.0f32; n];
        let rows: Vec<u32> = (0..n as u32).filter(|r| r % 3 != 0).collect();
        let mut dense = Histogram::zeros(binned.total_bins());
        dense.build(&binned, &rows, &grad, &hess);
        let exec = Executor::scoped(3);
        for row_shards in [1usize, 2, 4] {
            for feat_shards in [1usize, 2, 3] {
                let rowp = RowPartition::new(n, row_shards);
                let featp = FeaturePartition::new(&binned, feat_shards);
                let transport = LocalTransport::new(featp.n_shards());
                let got = aggregate_sharded(
                    &binned, &rows, &grad, &hess, &rowp, &featp, &transport, &exec, 0,
                );
                let at = format!("{row_shards}x{feat_shards} shards");
                for slot in 0..binned.total_bins() {
                    assert_eq!(got.grad[slot], dense.grad[slot], "grad slot {slot} ({at})");
                    assert_eq!(got.hess[slot], dense.hess[slot], "hess slot {slot} ({at})");
                    assert_eq!(got.count[slot], dense.count[slot], "count slot {slot} ({at})");
                }
                assert_eq!(got.totals, dense.totals, "totals ({at})");
                let mut tg: Vec<u32> = got.touched.clone();
                let mut td: Vec<u32> = dense.touched.clone();
                tg.sort_unstable();
                td.sort_unstable();
                assert_eq!(tg, td, "touched sets differ ({at})");
                // sparse exchange really is sparse: cross-shard traffic
                // is bounded by the touched slots, not the bin space
                if row_shards > 1 && feat_shards > 1 {
                    assert!(
                        (transport.bytes_sent() as usize) <= dense.touched.len() * 24 * row_shards,
                        "traffic exceeds touched-bin budget ({at})"
                    );
                }
            }
        }
    }

    fn accept_setup(n: usize, seed: u64) -> (Dataset, Arc<BinnedDataset>, FlatTree) {
        let ds = synthetic::realsim_like(n, seed);
        let b = Arc::new(BinnedDataset::from_dataset(&ds, 16).unwrap());
        let w = vec![1.0f32; n];
        let f0 = vec![0.0f32; n];
        let gh = logistic::grad_hess_loss(&f0, &ds.y, &w);
        let rows: Vec<u32> = (0..n as u32).collect();
        let params = TreeParams {
            max_leaves: 12,
            feature_rate: 0.9,
            ..Default::default()
        };
        let tree = build_tree(&b, &rows, &gh.grad, &gh.hess, &params, &mut Rng::new(seed));
        (ds, b, FlatTree::from_tree(&tree))
    }

    #[test]
    fn sharded_accept_pass_matches_fused_for_every_partition() {
        // the tentpole invariant: any RowPartition (including more
        // shards than executor threads — the claim loop) reproduces the
        // single-shard fused pass bit for bit
        let (ds, b, flat) = accept_setup(4_600, 41);
        let n = ds.n_rows();
        let sampler = BernoulliSampler::uniform(&ds, 0.6);
        let key = SampleKey { seed: 17, version: 5 };
        let inp = AcceptInputs {
            flat: Some(&flat),
            binned: &b,
            v: 0.2,
            y: &ds.y,
            m: &ds.m,
            sampler: &sampler,
            key,
            loss: ScalarLoss::Logistic,
            compute_target: true,
            want_eval: true,
        };
        let base = vec![0.1f32; n];
        let mut pool = ScratchPool::new();
        let mut f_ref = base.clone();
        let reference = fused_accept_pass(&inp, &mut f_ref, &Executor::scoped(1), &mut pool);
        for mode in [PoolMode::Persistent, PoolMode::Scoped] {
            for threads in [1usize, 2, 8] {
                for shards in [1usize, 2, 4, 8] {
                    let part = RowPartition::new(n, shards);
                    let exec = Executor::new(mode, threads);
                    let mut f = base.clone();
                    let out = sharded_accept_pass(&inp, &mut f, &part, &exec, &mut pool);
                    let at = format!("{shards} shards on {threads} threads ({mode:?})");
                    assert_eq!(f, f_ref, "F diverged at {at}");
                    assert_eq!(out.weights, reference.weights, "weights diverged at {at}");
                    assert_eq!(out.rows, reference.rows, "rows diverged at {at}");
                    assert_eq!(out.grad, reference.grad, "grad diverged at {at}");
                    assert_eq!(out.hess, reference.hess, "hess diverged at {at}");
                    assert_eq!(out.eval, reference.eval, "eval diverged at {at}");
                }
            }
        }
    }

    #[test]
    fn sharded_pass_scratch_returns_to_the_pool() {
        let (ds, b, flat) = accept_setup(2_600, 43);
        let sampler = BernoulliSampler::uniform(&ds, 0.5);
        let part = RowPartition::new(ds.n_rows(), 4);
        let exec = Executor::new(PoolMode::Persistent, 2);
        let mut pool = ScratchPool::new();
        let mut f = vec![0.0f32; ds.n_rows()];
        for v in 0..4u64 {
            let inp = AcceptInputs {
                flat: Some(&flat),
                binned: &b,
                v: 0.2,
                y: &ds.y,
                m: &ds.m,
                sampler: &sampler,
                key: SampleKey { seed: 2, version: v },
                loss: ScalarLoss::Logistic,
                compute_target: true,
                want_eval: v % 2 == 0,
            };
            sharded_accept_pass(&inp, &mut f, &part, &exec, &mut pool);
        }
        // one scratch per shard slot at most, all back in the pool
        assert!(pool.allocated() <= part.n_shards(), "allocated {}", pool.allocated());
        assert_eq!(pool.idle(), pool.allocated(), "scratch leaked");
    }
}
