//! Server side of the PS: state machine + shared board.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

use anyhow::Result;

use crate::config::{GradMode, TrainConfig};
use crate::data::sparse::CsrMatrix;
use crate::data::{BinnedDataset, Dataset};
use crate::forest::score::{self, ScoreMode, ScratchPool, ROW_BLOCK};
use crate::forest::Forest;
use crate::loss::{multiclass, scalar_base_score, ScalarLoss};
use crate::metrics::{CurvePoint, LossCurve, StalenessStats, StepStats};
use crate::runtime::GradientEngine;
use crate::sampling::{BernoulliSampler, SampleKey};
use crate::tree::{FlatTree, Tree};
use crate::util::timer::PhaseTimer;
use crate::util::{Executor, Stopwatch};

use super::messages::TargetSnapshot;
use super::shard::{fused_accept_pass, AcceptInputs, TargetMode};
use super::sharded::{sharded_accept_pass, RowPartition, ShardVersions};

/// The shared pull/push surface between server and workers.
///
/// Publishing is an Arc pointer swap under a short write lock; pulling is
/// a pointer clone under a read lock — workers never copy target vectors.
///
/// Under the sharded PS (`ps_shards>1`) the version carried by the
/// published snapshot is a *composition* of per-shard versions
/// ([`super::sharded::compose_version`]): the server advances every
/// shard's cell and publishes the composed minimum, so a board reader
/// still sees one monotone version without any shard-spanning lock.
#[derive(Debug)]
pub struct Board {
    snapshot: RwLock<Arc<TargetSnapshot>>,
    shutdown: AtomicBool,
    /// Per-worker liveness counters (supervised runs only — the default
    /// board allocates none, so the unsupervised worker loop stays
    /// atomic-free; see [`Board::beat`]).
    heartbeats: Vec<AtomicU64>,
}

impl Board {
    /// A fresh board holding the empty version-0 snapshot.
    pub fn new() -> Board {
        Board {
            snapshot: RwLock::new(Arc::new(TargetSnapshot::empty())),
            shutdown: AtomicBool::new(false),
            heartbeats: Vec::new(),
        }
    }

    /// A board with one heartbeat cell per worker — what the supervised
    /// async trainer allocates so worker liveness is observable.
    pub fn with_heartbeats(n_workers: usize) -> Board {
        Board {
            heartbeats: (0..n_workers).map(|_| AtomicU64::new(0)).collect(),
            ..Board::new()
        }
    }

    /// Publish a new target version (server only). Returns `false` and
    /// leaves the board untouched after shutdown — the poisoned-state
    /// guard: once a run is stopped, nothing (a racing supervisor, a
    /// late server loop) can resurrect worker activity by publishing a
    /// fresh target into it.
    pub fn publish(&self, s: TargetSnapshot) -> bool {
        if self.is_shutdown() {
            return false;
        }
        *self.snapshot.write().unwrap() = Arc::new(s);
        true
    }

    /// Latest published version. Derived from the snapshot itself (one
    /// read lock) rather than a side-channel atomic: an earlier version
    /// stored the counter *after* the snapshot swap, so `version()`
    /// could lag a snapshot a concurrent `pull()` had already returned.
    /// Reading the snapshot's own version makes the two views
    /// impossible to tear apart.
    pub fn version(&self) -> u64 {
        self.snapshot.read().unwrap().version
    }

    /// Pull the current target (workers). O(1).
    pub fn pull(&self) -> Arc<TargetSnapshot> {
        self.snapshot.read().unwrap().clone()
    }

    /// Flag shutdown; workers observe it on their next poll. Idempotent:
    /// returns `true` only for the call that actually transitioned the
    /// board, so a supervisor retiring a dead worker while the server
    /// shuts down cannot double-shutdown — later calls are no-ops that
    /// report `false`.
    pub fn request_shutdown(&self) -> bool {
        !self.shutdown.swap(true, Ordering::AcqRel)
    }

    /// Whether shutdown has been requested.
    pub fn is_shutdown(&self) -> bool {
        self.shutdown.load(Ordering::Acquire)
    }

    /// Bump worker `wid`'s heartbeat (one relaxed add per build cycle).
    /// No-op on a board without heartbeat cells — the default
    /// unsupervised path never pays the atomic.
    pub fn beat(&self, wid: usize) {
        if let Some(cell) = self.heartbeats.get(wid) {
            cell.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Worker `wid`'s heartbeat count (0 on a board without cells).
    pub fn heartbeat(&self, wid: usize) -> u64 {
        self.heartbeats
            .get(wid)
            .map(|cell| cell.load(Ordering::Relaxed))
            .unwrap_or(0)
    }
}

impl Default for Board {
    fn default() -> Self {
        Self::new()
    }
}

/// Held-out evaluation state (margins updated incrementally per tree).
struct TestSet {
    x: CsrMatrix,
    y: Vec<f32>,
    w: Vec<f32>,
    f: Vec<f32>,
}

/// Outcome of applying one pushed tree.
#[derive(Debug, Clone, Copy)]
pub struct ApplyOutcome {
    /// Realised delay τ = version at apply − version pulled.
    pub staleness: u64,
    /// False if the bounded-staleness filter dropped the push.
    pub accepted: bool,
    /// Trees accepted so far.
    pub n_trees: usize,
}

/// The server state machine of Algorithm 3. Owns everything on the
/// produce-target path; drives the gradient engine (AOT/PJRT when
/// artifacts are present). Not `Send` (PJRT handles) — lives on the
/// thread that runs the accept loop.
///
/// Per accepted tree the server runs one of two accept pipelines
/// (`cfg.target`): the **fused** row-sharded pass (`ps/shard.rs`,
/// default) collapsing F-update + sampling + target + eval into one
/// sweep, or the **serial** reference path with separate sweeps. Both
/// draw sampling passes from the same counter-based keys and reduce
/// eval sums through the same blocked fold, so they produce
/// bit-identical F vectors, targets and loss curves.
///
/// Threads for either pipeline come from the core's [`Executor`],
/// constructed once per server lifetime from `cfg.pool` /
/// `cfg.score_threads`: `pool=persistent` (default) parks the workers
/// in a [`crate::util::ScorePool`] between trees, so per-tree dispatch
/// is a condvar wake instead of `score_threads` OS thread spawns.
pub struct ServerCore {
    cfg: TrainConfig,
    binned: Arc<BinnedDataset>,
    train_y: Vec<f32>,
    train_m: Vec<f32>,
    engine: GradientEngine,
    sampler: BernoulliSampler,
    /// Seed of the server's sampling pass keys: pass j is the pure
    /// function of `(sample_seed, j, row)` — no sequential RNG state.
    sample_seed: u64,
    /// The scalar loss driving every per-row kernel (`cfg.loss`). Under
    /// `loss=multiclass` this stays at its `Logistic` default and is
    /// never consulted — the multiclass accept path bypasses the scalar
    /// kernels entirely.
    scalar: ScalarLoss,
    /// Parallel margin vectors: 1 for the scalar losses, `n_classes`
    /// under `loss=multiclass` (class-major F of length `k·n`).
    k: usize,
    /// Multiclass only: the sampled weights of the current target pass,
    /// held between target production and the next accept's per-leaf
    /// refit sums (scalar runs keep this empty).
    mc_w: Vec<f32>,
    /// Current prediction vector **F** over training rows (class-major,
    /// length `k · n_rows`; `k = 1` for scalar losses).
    f: Vec<f32>,
    /// Pooled scoring scratch for the blocked F-update (step 2) — row-id
    /// blocks + partition stacks recycled across every accepted tree.
    score_pool: ScratchPool,
    /// The execution resource behind every parallel scoring section,
    /// built once from `cfg.pool` / `cfg.score_threads`: a server-lifetime
    /// [`crate::util::ScorePool`] of parked workers (`pool=persistent`,
    /// default) or per-section scoped spawns (`pool=scoped`).
    exec: Executor,
    /// Row ownership of the server shards (`cfg.ps_shards`, clamped to
    /// the block count). One shard — the default — is the single-server
    /// layout; more route the fused pass through `ps/sharded.rs`.
    partition: RowPartition,
    /// Per-shard published versions; the snapshot's version is their
    /// composition (min), identical to the raw counter at one shard.
    shard_versions: ShardVersions,
    /// The accepted forest F(x).
    pub forest: Forest,
    test: Option<TestSet>,
    /// Loss-curve points recorded every `eval_every` accepted trees.
    pub curve: LossCurve,
    /// Realised staleness distribution over accepted/rejected pushes.
    pub staleness: StalenessStats,
    /// Effective step length applied to every accepted push: constant
    /// `step_length` under `step=fixed`, `StepMode::effective(v, τ)`
    /// under `step=adaptive`.
    pub steps: StepStats,
    /// Per-phase wall-clock accounting of the accept path.
    pub timer: PhaseTimer,
    clock: Stopwatch,
    current: TargetSnapshot,
}

impl ServerCore {
    /// Initialise per Algorithm 3's server prologue: constant tree at the
    /// weighted mean label, then compute and hold `L'^0_random`.
    pub fn new(
        cfg: &TrainConfig,
        train: &Dataset,
        binned: Arc<BinnedDataset>,
        test: Option<&Dataset>,
        engine: GradientEngine,
    ) -> Result<ServerCore> {
        cfg.validate()?;
        let scalar = cfg.scalar_loss();
        let k = if scalar.is_some() { 1 } else { cfg.n_classes };
        if let Some(s) = scalar {
            anyhow::ensure!(
                engine.loss() == s,
                "engine was built for loss {:?} but the config trains loss={} — \
                 construct it with GradientEngine::auto_for(dir, cfg.scalar_loss())",
                engine.loss(),
                cfg.loss.as_str()
            );
        } else {
            validate_class_labels(&train.y, k, "train")?;
            if let Some(t) = test {
                validate_class_labels(&t.y, k, "test")?;
            }
        }
        let scalar = scalar.unwrap_or_default();
        // multiclass starts every class margin at 0 (uniform softmax);
        // scalar losses keep their per-loss base (positive-rate logit
        // for logistic, weighted label mean for squared/huber)
        let base = if k > 1 {
            0.0
        } else {
            scalar_base_score(scalar, &train.y, train.positive_rate())
        };
        let forest = Forest::new(base);
        let f = vec![base; k * train.n_rows()];
        let sampler = BernoulliSampler::uniform(train, cfg.sampling_rate);
        let test = test.map(|t| TestSet {
            f: vec![base; k * t.n_rows()],
            y: t.y.clone(),
            w: t.m.clone(),
            x: t.x.clone(),
        });
        let partition = RowPartition::new(train.n_rows(), cfg.ps_shards);
        let shard_versions = ShardVersions::new(partition.n_shards());
        let mut core = ServerCore {
            cfg: cfg.clone(),
            binned,
            train_y: train.y.clone(),
            train_m: train.m.clone(),
            engine,
            sampler,
            sample_seed: cfg.seed ^ SERVER_SEED_SALT,
            scalar,
            k,
            mc_w: Vec::new(),
            f,
            score_pool: ScratchPool::new(),
            exec: Executor::new(cfg.pool, cfg.score_threads),
            partition,
            shard_versions,
            forest,
            test,
            curve: LossCurve::default(),
            staleness: StalenessStats::default(),
            steps: StepStats::default(),
            timer: PhaseTimer::new(),
            clock: Stopwatch::new(),
            current: TargetSnapshot::empty(),
        };
        if core.k > 1 {
            core.produce_target_multiclass(0)?;
        } else {
            core.produce_target(0)?;
        }
        core.eval_point()?; // curve point at 0 trees
        Ok(core)
    }

    /// The engine kind actually in use (logging / EXPERIMENTS.md).
    pub fn engine_kind(&self) -> crate::runtime::EngineKind {
        self.engine.kind()
    }

    /// Current target snapshot (version = #accepted trees).
    pub fn snapshot(&self) -> TargetSnapshot {
        self.current.clone()
    }

    /// Accepted pushes so far (== the current target version). For the
    /// scalar losses this is the forest size; under `loss=multiclass`
    /// one accepted push lands K class trees, so this counts *rounds*
    /// (`forest.n_trees() / n_classes`).
    pub fn n_trees(&self) -> usize {
        self.forest.n_trees() / self.k
    }

    /// Apply one pushed tree (Algorithm 3 server steps 1–5). Returns the
    /// outcome; on acceptance the new target has been produced and
    /// `snapshot()` reflects version j+1.
    ///
    /// The effective step length of the push is
    /// `cfg.step.effective(cfg.step_length, τ)` — the constant v under
    /// `step=fixed`, the Proposition-1-style shrink `v/(1+τ)` under
    /// `step=adaptive` (DESIGN.md §17). A pure function of the recorded
    /// τ, so replaying a τ trace reproduces the run bit for bit.
    pub fn apply_tree(&mut self, tree: Tree, based_on: u64) -> Result<ApplyOutcome> {
        let version = self.n_trees() as u64;
        let tau = version.saturating_sub(based_on);
        if let Some(max_tau) = self.cfg.max_staleness {
            if tau > max_tau {
                self.staleness.record_rejected();
                return Ok(ApplyOutcome {
                    staleness: tau,
                    accepted: false,
                    n_trees: self.n_trees(),
                });
            }
        }
        self.staleness.record(tau);
        let v_eff = self.cfg.step.effective(self.cfg.step_length, tau);
        self.steps.record(v_eff);

        if self.k > 1 {
            self.apply_tree_multiclass(tree, v_eff)?;
        } else {
            match self.cfg.target {
                TargetMode::Fused => self.apply_tree_fused(tree, v_eff)?,
                TargetMode::Serial => self.apply_tree_serial(tree, v_eff)?,
            }
        }
        Ok(ApplyOutcome {
            staleness: tau,
            accepted: true,
            n_trees: self.n_trees(),
        })
    }

    /// Replay one checkpointed tree during `--resume` restore: applied
    /// as perfectly fresh (`based_on` = current version), so the accept
    /// pipeline runs the exact F-update/target/eval arithmetic — in the
    /// same deterministic operation order — that produced this state in
    /// the original run. After replaying a checkpoint's k trees, `f`,
    /// the targets, the sampler draws (keyed on `(sample_seed, version,
    /// row)`, all replayed versions included) and the loss curve are
    /// bit-identical to the uninterrupted run at tree k; only wall-clock
    /// fields differ. Errors if the accept pipeline rejects the tree —
    /// impossible for a fresh push, so any failure means a corrupt or
    /// mismatched checkpoint.
    pub fn replay_tree(&mut self, tree: Tree) -> Result<()> {
        self.replay_tree_with(tree, self.cfg.step_length)
    }

    /// [`ServerCore::replay_tree`] at an *explicit* step length: replay
    /// the tree with the exact v the original accept applied (recorded
    /// per tree in the checkpoint's forest). Under `step=adaptive` a
    /// push accepted at τ>0 shrank its v below `step_length`; replaying
    /// at the fresh-push τ=0 would recompute a different v, so restore
    /// hands the recorded value back in instead (`coordinator/
    /// checkpoint.rs`). Under `step=fixed` the recorded v always equals
    /// `step_length` and this is exactly the old replay.
    pub fn replay_tree_with(&mut self, tree: Tree, v: f32) -> Result<()> {
        anyhow::ensure!(
            self.k == 1,
            "checkpoint replay: multiclass forests replay in rounds of {} class trees \
             (replay_round), not single trees",
            self.k
        );
        self.staleness.record(0);
        self.steps.record(v);
        match self.cfg.target {
            TargetMode::Fused => self.apply_tree_fused(tree, v),
            TargetMode::Serial => self.apply_tree_serial(tree, v),
        }
    }

    /// Replay one checkpointed **multiclass round**: the K class trees a
    /// single accept pushed, leaves already refit, at the recorded step
    /// length. Margin updates, the next target pass and the eval point
    /// re-run in the original operation order, so the restored state is
    /// bit-identical to the uninterrupted run after that round.
    pub fn replay_round(&mut self, trees: Vec<Tree>, v: f32) -> Result<()> {
        anyhow::ensure!(
            self.k > 1,
            "checkpoint replay: replay_round is multiclass-only (loss={})",
            self.cfg.loss.as_str()
        );
        anyhow::ensure!(
            trees.len() == self.k,
            "checkpoint replay: round carries {} trees, expected n_classes={}",
            trees.len(),
            self.k
        );
        self.staleness.record(0);
        self.steps.record(v);
        self.apply_class_trees(trees, v);
        let new_version = self.n_trees() as u64;
        self.produce_target_multiclass(new_version)?;
        if self.eval_due(self.n_trees()) {
            self.eval_point()?;
        }
        Ok(())
    }

    /// Whether the tree that takes the accept counter to `n_after`
    /// records a loss-curve point.
    fn eval_due(&self, n_after: usize) -> bool {
        n_after % self.cfg.eval_every == 0 || n_after == self.cfg.n_trees
    }

    /// The fused accept pipeline: steps 2–4 (and the eval sums, when
    /// due) in **one sharded pass** over the training rows
    /// (`ps/shard.rs`), instead of the serial path's 3–4 separate
    /// sweeps. Held-out margins keep their own incremental blocked
    /// update — the fused pass covers the training side.
    fn apply_tree_fused(&mut self, tree: Tree, v: f32) -> Result<()> {
        let flat = self
            .timer
            .time("server/flatten_tree", || FlatTree::from_tree(&tree));
        let new_version = self.forest.n_trees() as u64 + 1;
        let eval_due = self.eval_due(self.forest.n_trees() + 1);
        // AOT engines are not shard-wise: keep scoring + sampling fused,
        // fall back to whole-vector engine calls for target and eval
        let native = self.engine.supports_ranges();
        let inp = AcceptInputs {
            flat: Some(&flat),
            binned: &self.binned,
            v,
            y: &self.train_y,
            m: &self.train_m,
            sampler: &self.sampler,
            key: SampleKey {
                seed: self.sample_seed,
                version: new_version,
            },
            loss: self.scalar,
            compute_target: native,
            want_eval: eval_due && native,
        };
        let t0 = std::time::Instant::now();
        // one server shard: the thread-carved fused pass; more: the same
        // kernel carved at the row partition's boundaries (bit-identical
        // for every shard count — `ps/sharded.rs`)
        let fused = if self.partition.n_shards() > 1 {
            sharded_accept_pass(
                &inp,
                &mut self.f,
                &self.partition,
                &self.exec,
                &mut self.score_pool,
            )
        } else {
            fused_accept_pass(&inp, &mut self.f, &self.exec, &mut self.score_pool)
        };
        self.timer.record("server/fused_pass", t0.elapsed());
        if let Some(test) = &mut self.test {
            let t0 = std::time::Instant::now();
            score::add_tree_raw(
                &flat,
                &test.x,
                v,
                &mut test.f,
                &self.exec,
                &mut self.score_pool,
            );
            self.timer.record("server/update_f_test", t0.elapsed());
        }
        self.forest.push(v, tree);

        let (grad, hess) = if native {
            let hess = match self.cfg.grad_mode {
                GradMode::Newton => fused.hess,
                // gradient mode: weighted-LS fit => h_i := m'_i (moved,
                // not cloned — the pass result is consumed right here)
                GradMode::Gradient => fused.weights,
            };
            (fused.grad, hess)
        } else {
            let t0 = std::time::Instant::now();
            let gh = self
                .engine
                .grad_hess_loss(&self.f, &self.train_y, &fused.weights)?;
            self.timer.record("server/produce_target", t0.elapsed());
            let hess = match self.cfg.grad_mode {
                GradMode::Newton => gh.hess,
                GradMode::Gradient => fused.weights,
            };
            (gh.grad, hess)
        };
        self.current = TargetSnapshot {
            version: self.advance_shards(new_version),
            grad: Arc::new(grad),
            hess: Arc::new(hess),
            rows: Arc::new(fused.rows),
        };

        if eval_due {
            let t0 = std::time::Instant::now();
            let (l, _e, w) = match fused.eval {
                Some(sums) => sums,
                None => self
                    .engine
                    .eval_sums_blocked(&self.f, &self.train_y, &self.train_m, ROW_BLOCK)?,
            };
            let train_loss = if w > 0.0 { l / w } else { 0.0 };
            let (test_loss, test_error) = self.test_eval()?;
            self.timer.record("server/eval", t0.elapsed());
            self.curve.push(CurvePoint {
                n_trees: self.forest.n_trees(),
                train_loss,
                test_loss,
                test_error,
                wall_secs: self.clock.elapsed(),
            });
        }
        Ok(())
    }

    /// The serial reference pipeline: separate sweeps for scoring,
    /// sampling, target production and eval. Same counter-based sample
    /// keys and same blocked eval reduction as the fused path, so the
    /// two stay bit-identical (the shard-invariance tests' anchor).
    fn apply_tree_serial(&mut self, tree: Tree, v: f32) -> Result<()> {
        // step 2: F^j = F^{j-1} + v * Tree. The blocked SoA engine and the
        // per-row enum reference produce bit-identical F vectors (same f32
        // ops in the same per-row order); `scoring=perrow` keeps the
        // reference selectable for equivalence tests and ablation.
        match self.cfg.scoring {
            ScoreMode::Flat => {
                let flat = self
                    .timer
                    .time("server/flatten_tree", || FlatTree::from_tree(&tree));
                let t0 = std::time::Instant::now();
                score::add_tree_binned(
                    &flat,
                    &self.binned,
                    v,
                    &mut self.f,
                    &self.exec,
                    &mut self.score_pool,
                );
                self.timer.record("server/update_f", t0.elapsed());
                if let Some(test) = &mut self.test {
                    let t0 = std::time::Instant::now();
                    score::add_tree_raw(
                        &flat,
                        &test.x,
                        v,
                        &mut test.f,
                        &self.exec,
                        &mut self.score_pool,
                    );
                    self.timer.record("server/update_f_test", t0.elapsed());
                }
            }
            ScoreMode::PerRow => {
                let t0 = std::time::Instant::now();
                for r in 0..self.f.len() {
                    self.f[r] += v * tree.predict_binned(&self.binned, r);
                }
                self.timer.record("server/update_f", t0.elapsed());
                if let Some(test) = &mut self.test {
                    let t0 = std::time::Instant::now();
                    for r in 0..test.f.len() {
                        test.f[r] += v * tree.predict_raw(&test.x, r);
                    }
                    self.timer.record("server/update_f_test", t0.elapsed());
                }
            }
        }
        self.forest.push(v, tree);

        // steps 3–5: resample, produce L'^{j+1}_random, publish
        let new_version = self.forest.n_trees() as u64;
        self.produce_target(new_version)?;

        if self.eval_due(self.forest.n_trees()) {
            self.eval_point()?;
        }
        Ok(())
    }

    /// The multiclass accept pipeline (whole-vector, the same shape as
    /// the AOT bucket fallback): one structure pass shared by all K
    /// classes. The pushed tree's *structure* routes every training row
    /// to a leaf once; per-leaf per-class Newton sums over the round's
    /// sampled weights refit K leaf-value sets; the K class clones then
    /// update the class-major margins like K serial scalar accepts and
    /// land in the forest together. Bypasses `target=`/`ps_shards` —
    /// the scalar fused kernels never see multiclass (DESIGN.md §17).
    fn apply_tree_multiclass(&mut self, tree: Tree, v: f32) -> Result<()> {
        let n = self.train_y.len();
        let k = self.k;
        let lambda = self.cfg.tree.lambda;
        let t0 = std::time::Instant::now();
        let n_nodes = tree.n_nodes();
        let mut gsum = vec![0.0f64; n_nodes * k];
        let mut hsum = vec![0.0f64; n_nodes * k];
        let mut scores = vec![0.0f32; k];
        for i in 0..n {
            let wi = self.mc_w[i];
            if wi == 0.0 {
                continue; // unsampled rows are exact no-ops
            }
            let leaf = tree.leaf_of_binned(&self.binned, i) as usize;
            multiclass::probs_at(&self.f, k, n, i, &mut scores);
            let yc = self.train_y[i] as usize;
            for (c, &p) in scores.iter().enumerate() {
                let ind = if c == yc { 1.0f32 } else { 0.0 };
                gsum[leaf * k + c] += (wi * (p - ind)) as f64;
                hsum[leaf * k + c] += (wi * p * (1.0 - p)) as f64;
            }
        }
        let class_trees: Vec<Tree> = (0..k)
            .map(|c| {
                tree.with_leaf_values(&mut |node| {
                    let (g, h) = (gsum[node * k + c], hsum[node * k + c]);
                    // same guard as the builder's leaf_value: a leaf no
                    // sampled row reached predicts 0
                    if h + lambda <= 0.0 {
                        0.0
                    } else {
                        (-g / (h + lambda)) as f32
                    }
                })
            })
            .collect();
        self.timer.record("server/multiclass_refit", t0.elapsed());
        self.apply_class_trees(class_trees, v);
        let new_version = self.n_trees() as u64;
        self.produce_target_multiclass(new_version)?;
        if self.eval_due(self.n_trees()) {
            self.eval_point()?;
        }
        Ok(())
    }

    /// Push K refit class trees and apply their margin updates — the
    /// tail shared by the live multiclass accept and checkpoint replay
    /// ([`ServerCore::replay_round`]), so both run the identical f32
    /// operation order per class, per row.
    fn apply_class_trees(&mut self, trees: Vec<Tree>, v: f32) {
        let n = self.train_y.len();
        let t0 = std::time::Instant::now();
        for (c, tree) in trees.into_iter().enumerate() {
            for r in 0..n {
                self.f[c * n + r] += v * tree.predict_binned(&self.binned, r);
            }
            if let Some(test) = &mut self.test {
                let nt = test.y.len();
                for r in 0..nt {
                    test.f[c * nt + r] += v * tree.predict_raw(&test.x, r);
                }
            }
            self.forest.push(v, tree);
        }
        self.timer.record("server/update_f", t0.elapsed());
    }

    /// Multiclass steps 3–5: one keyed sampling pass (the same
    /// counter-based keys as the scalar paths), softmax targets for the
    /// *structure class* `version mod K`, publish. The full weight
    /// vector is held for the next accept's refit sums; the published
    /// grad/hess is the one class whose descent the workers' structure
    /// tree follows — round-robin, so every class shapes structure
    /// equally often.
    fn produce_target_multiclass(&mut self, version: u64) -> Result<()> {
        let key = SampleKey {
            seed: self.sample_seed,
            version,
        };
        let pass = self.timer.time("server/sample", || self.sampler.draw(key));
        let c = version as usize % self.k;
        let t0 = std::time::Instant::now();
        let gh = multiclass::grad_hess_class(&self.f, &self.train_y, &pass.weights, self.k, c);
        self.timer.record("server/produce_target", t0.elapsed());
        let hess = match self.cfg.grad_mode {
            GradMode::Newton => gh.hess,
            // gradient mode: weighted-LS fit => h_i := m'_i
            GradMode::Gradient => pass.weights.clone(),
        };
        self.mc_w = pass.weights;
        self.current = TargetSnapshot {
            version: self.advance_shards(version),
            grad: Arc::new(gh.grad),
            hess: Arc::new(hess),
            rows: Arc::new(pass.rows),
        };
        Ok(())
    }

    /// Sample Q (pass keyed on `version`) and compute the stochastic
    /// target on the sub-dataset. Used by the serial path and by both
    /// pipelines' shared init (version 0 has no tree to fuse with).
    fn produce_target(&mut self, version: u64) -> Result<()> {
        let key = SampleKey {
            seed: self.sample_seed,
            version,
        };
        let pass = self.timer.time("server/sample", || self.sampler.draw(key));
        let (f, y) = (&self.f, &self.train_y);
        let gh = {
            let engine = &mut self.engine;
            let timer = &mut self.timer;
            let t0 = std::time::Instant::now();
            let gh = engine.grad_hess_loss(f, y, &pass.weights)?;
            timer.record("server/produce_target", t0.elapsed());
            gh
        };
        let hess = match self.cfg.grad_mode {
            GradMode::Newton => gh.hess,
            // gradient mode: weighted-LS fit => h_i := m'_i
            GradMode::Gradient => pass.weights.clone(),
        };
        self.current = TargetSnapshot {
            version: self.advance_shards(version),
            grad: Arc::new(gh.grad),
            hess: Arc::new(hess),
            rows: Arc::new(pass.rows),
        };
        Ok(())
    }

    /// Advance every shard's version cell to `new_version` and return
    /// the composed (min) version for the published snapshot. With one
    /// shard this is the raw counter; with more, the composition step
    /// itself is exercised on every publish — a shard left behind would
    /// hold the published version back, which the staleness tests pin.
    fn advance_shards(&self, new_version: u64) -> u64 {
        for s in 0..self.shard_versions.n_shards() {
            self.shard_versions.publish(s, new_version);
        }
        self.shard_versions.composed()
    }

    /// Row ownership of the server shards (test/diagnostic surface).
    pub fn row_partition(&self) -> &RowPartition {
        &self.partition
    }

    /// Per-shard published versions (test/diagnostic surface).
    pub fn shard_versions(&self) -> &ShardVersions {
        &self.shard_versions
    }

    /// Held-out metrics on the incrementally-maintained test margins.
    fn test_eval(&mut self) -> Result<(f64, f64)> {
        if let Some(test) = &self.test {
            let (tl, te, tw) = if self.k > 1 {
                multiclass::eval_sums(&test.f, &test.y, &test.w, self.k)
            } else {
                self.engine
                    .eval_sums_blocked(&test.f, &test.y, &test.w, ROW_BLOCK)?
            };
            if tw > 0.0 {
                Ok((tl / tw, te / tw))
            } else {
                Ok((f64::NAN, f64::NAN))
            }
        } else {
            Ok((f64::NAN, f64::NAN))
        }
    }

    /// Record a loss-curve point (full-weight train loss + test metrics)
    /// with the blocked eval reduction both accept pipelines share
    /// (multiclass: the softmax/argmax sweep over the class-major state).
    fn eval_point(&mut self) -> Result<()> {
        let t0 = std::time::Instant::now();
        let (l, _e, w) = if self.k > 1 {
            multiclass::eval_sums(&self.f, &self.train_y, &self.train_m, self.k)
        } else {
            self.engine
                .eval_sums_blocked(&self.f, &self.train_y, &self.train_m, ROW_BLOCK)?
        };
        let train_loss = if w > 0.0 { l / w } else { 0.0 };
        let (test_loss, test_error) = self.test_eval()?;
        self.timer.record("server/eval", t0.elapsed());
        self.curve.push(CurvePoint {
            n_trees: self.n_trees(),
            train_loss,
            test_loss,
            test_error,
            wall_secs: self.clock.elapsed(),
        });
        Ok(())
    }
}

/// Salt separating the server's sampling stream from worker streams that
/// share the same user seed.
const SERVER_SEED_SALT: u64 = 0x9e37_79b9_7f4a_7c15;

/// `loss=multiclass` labels must be integer class ids in `[0, K)` —
/// anything else (a binary {0,1} corpus with K=5, regression targets,
/// a stray 7.5) trains garbage silently, so it is refused by name here.
fn validate_class_labels(y: &[f32], k: usize, split: &str) -> Result<()> {
    for (i, &v) in y.iter().enumerate() {
        let ok = v.is_finite() && v >= 0.0 && v.fract() == 0.0 && (v as usize) < k;
        if !ok {
            anyhow::bail!(
                "loss=multiclass: {split} row {i} has label {v}, expected an integer \
                 class id in [0, {k}) — check n_classes against the dataset"
            );
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::util::Rng;

    fn mini_cfg(n_trees: usize) -> TrainConfig {
        let mut cfg = TrainConfig::default();
        cfg.n_trees = n_trees;
        cfg.step_length = 0.3;
        cfg.sampling_rate = 0.9;
        cfg.workers = 1;
        cfg.tree.max_leaves = 8;
        cfg.tree.feature_rate = 1.0;
        cfg.eval_every = 1;
        cfg
    }

    fn core_on(ds: &Dataset, cfg: &TrainConfig) -> ServerCore {
        let binned = Arc::new(BinnedDataset::from_dataset(ds, cfg.max_bins).unwrap());
        ServerCore::new(cfg, ds, binned, None, GradientEngine::native()).unwrap()
    }

    #[test]
    fn board_version_never_lags_a_pulled_snapshot() {
        // regression: version was stored *after* the snapshot swap, so a
        // concurrent reader could pull snapshot v+1 while version() still
        // said v. Deriving version from the snapshot closes the window:
        // for any interleaving, a pull followed by version() must see
        // version() >= pulled.version.
        let board = Arc::new(Board::new());
        std::thread::scope(|s| {
            let readers: Vec<_> = (0..3)
                .map(|_| {
                    let b = board.clone();
                    s.spawn(move || {
                        while !b.is_shutdown() {
                            let snap = b.pull();
                            let v = b.version();
                            assert!(
                                v >= snap.version,
                                "version() {v} lagged pulled snapshot {}",
                                snap.version
                            );
                        }
                    })
                })
                .collect();
            for v in 1..=2_000u64 {
                board.publish(TargetSnapshot {
                    version: v,
                    grad: Arc::new(vec![0.0; 4]),
                    hess: Arc::new(vec![0.0; 4]),
                    rows: Arc::new(vec![0]),
                });
            }
            board.request_shutdown();
            for r in readers {
                r.join().unwrap();
            }
        });
        assert_eq!(board.version(), 2_000);
    }

    fn snapshot_v(version: u64) -> TargetSnapshot {
        TargetSnapshot {
            version,
            grad: Arc::new(vec![0.0; 2]),
            hess: Arc::new(vec![0.0; 2]),
            rows: Arc::new(vec![0, 1]),
        }
    }

    #[test]
    fn request_shutdown_is_idempotent() {
        let board = Board::new();
        assert!(!board.is_shutdown());
        assert!(board.request_shutdown(), "first call transitions");
        assert!(board.is_shutdown());
        assert!(!board.request_shutdown(), "second call is a no-op");
        assert!(!board.request_shutdown(), "and so is every later one");
        assert!(board.is_shutdown());
    }

    #[test]
    fn publish_after_shutdown_is_refused() {
        let board = Board::new();
        assert!(board.publish(snapshot_v(1)));
        board.request_shutdown();
        // poisoned-state guard: the stopped board keeps its last target
        assert!(!board.publish(snapshot_v(2)));
        assert_eq!(board.version(), 1);
        assert_eq!(board.pull().version, 1);
    }

    #[test]
    fn heartbeats_count_per_worker_and_default_board_has_none() {
        let plain = Board::new();
        plain.beat(0); // no cells: silently a no-op
        assert_eq!(plain.heartbeat(0), 0);
        let sup = Board::with_heartbeats(2);
        sup.beat(0);
        sup.beat(0);
        sup.beat(1);
        sup.beat(7); // out of range: ignored
        assert_eq!(sup.heartbeat(0), 2);
        assert_eq!(sup.heartbeat(1), 1);
        assert_eq!(sup.heartbeat(7), 0);
    }

    #[test]
    fn init_publishes_version_zero_with_sampled_target() {
        let ds = synthetic::realsim_like(300, 1);
        let cfg = mini_cfg(5);
        let core = core_on(&ds, &cfg);
        let s = core.snapshot();
        assert_eq!(s.version, 0);
        assert!(s.n_sampled() > 200); // rate 0.9
        assert_eq!(s.grad.len(), 300);
        assert_eq!(core.curve.points.len(), 1); // initial eval point
    }

    #[test]
    fn apply_tree_advances_version_and_records_staleness() {
        let ds = synthetic::realsim_like(200, 2);
        let cfg = mini_cfg(5);
        let mut core = core_on(&ds, &cfg);
        let s = core.snapshot();
        let mut rng = Rng::new(1);
        let tree = crate::tree::build_tree(
            &core.binned.clone(),
            &s.rows,
            &s.grad,
            &s.hess,
            &cfg.tree,
            &mut rng,
        );
        let out = core.apply_tree(tree, s.version).unwrap();
        assert!(out.accepted);
        assert_eq!(out.staleness, 0);
        assert_eq!(core.snapshot().version, 1);
        assert_eq!(core.n_trees(), 1);
    }

    #[test]
    fn bounded_staleness_rejects_old_pushes() {
        let ds = synthetic::realsim_like(200, 3);
        let mut cfg = mini_cfg(10);
        cfg.max_staleness = Some(0);
        let mut core = core_on(&ds, &cfg);
        let s0 = core.snapshot();
        let mut rng = Rng::new(2);
        let t1 = crate::tree::build_tree(
            &core.binned.clone(),
            &s0.rows,
            &s0.grad,
            &s0.hess,
            &cfg.tree,
            &mut rng,
        );
        let t2 = t1.clone();
        core.apply_tree(t1, 0).unwrap();
        // second push still based on version 0: tau = 1 > max 0 => rejected
        let out = core.apply_tree(t2, 0).unwrap();
        assert!(!out.accepted);
        assert_eq!(core.n_trees(), 1);
        assert_eq!(core.staleness.rejected, 1);
    }

    #[test]
    fn gradient_mode_uses_weights_as_hessian() {
        let ds = synthetic::realsim_like(100, 4);
        let mut cfg = mini_cfg(3);
        cfg.grad_mode = GradMode::Gradient;
        let core = core_on(&ds, &cfg);
        let s = core.snapshot();
        for &r in s.rows.iter().take(10) {
            // hess equals the sampling weight (1/0.9 for selected unit rows)
            assert!((s.hess[r as usize] - 1.0 / 0.9).abs() < 1e-3);
        }
    }

    #[test]
    fn flat_and_per_row_scoring_produce_identical_state() {
        // the acceptance bar for the blocked engine: both scorers yield
        // the same F vector, hence bit-identical targets and loss curves
        // 2600 rows: the train split exceeds 2 * ROW_BLOCK, so the flat
        // core takes the threaded (block-claiming) path. The flat core
        // runs the default fused accept pipeline, the per-row reference
        // requires target=serial — so this also pins fused ≡ serial.
        let ds = synthetic::realsim_like(2_600, 6);
        let mut rng0 = Rng::new(7);
        let (tr, te) = ds.split(0.25, &mut rng0);
        let binned = Arc::new(BinnedDataset::from_dataset(&tr, 16).unwrap());
        let mut cfg_flat = mini_cfg(8);
        cfg_flat.scoring = crate::forest::ScoreMode::Flat;
        cfg_flat.score_threads = 3;
        cfg_flat.pool = crate::util::PoolMode::Persistent;
        let mut cfg_ref = cfg_flat.clone();
        cfg_ref.target = TargetMode::Serial;
        cfg_ref.scoring = crate::forest::ScoreMode::PerRow;
        cfg_ref.score_threads = 1;
        cfg_ref.pool = crate::util::PoolMode::Scoped;
        let mut core_a =
            ServerCore::new(&cfg_flat, &tr, binned.clone(), Some(&te), GradientEngine::native())
                .unwrap();
        let mut core_b =
            ServerCore::new(&cfg_ref, &tr, binned.clone(), Some(&te), GradientEngine::native())
                .unwrap();
        let mut rng = Rng::new(9);
        for _ in 0..8 {
            let s = core_a.snapshot();
            let tree = crate::tree::build_tree(
                &binned, &s.rows, &s.grad, &s.hess, &cfg_flat.tree, &mut rng,
            );
            core_a.apply_tree(tree.clone(), s.version).unwrap();
            core_b.apply_tree(tree, core_b.snapshot().version).unwrap();
        }
        assert_eq!(core_a.f, core_b.f, "train F vectors diverged");
        let la: Vec<f64> = core_a.curve.points.iter().map(|p| p.train_loss).collect();
        let lb: Vec<f64> = core_b.curve.points.iter().map(|p| p.train_loss).collect();
        assert_eq!(la, lb, "loss curves diverged");
        let ta: Vec<f64> = core_a.curve.points.iter().map(|p| p.test_loss).collect();
        let tb: Vec<f64> = core_b.curve.points.iter().map(|p| p.test_loss).collect();
        assert_eq!(ta, tb, "test curves diverged");
        // pooled scratch reached steady state: at most score_threads buffers
        assert!(core_a.score_pool.allocated() <= 3);
    }

    #[test]
    fn fused_and_serial_accept_paths_are_bit_identical() {
        // the tentpole acceptance bar: one fused sharded pass per tree
        // (multi-thread) vs the serial reference's separate sweeps —
        // same F, same sampled rows/targets, same loss curves, same
        // staleness stats, at every tested thread count.
        let ds = synthetic::realsim_like(2_800, 61);
        let mut rng0 = Rng::new(3);
        let (tr, te) = ds.split(0.2, &mut rng0);
        let binned = Arc::new(BinnedDataset::from_dataset(&tr, 16).unwrap());
        let mut cfg_serial = mini_cfg(10);
        cfg_serial.target = TargetMode::Serial;
        cfg_serial.score_threads = 1;
        cfg_serial.pool = crate::util::PoolMode::Scoped;
        cfg_serial.eval_every = 2;
        let mut serial = ServerCore::new(
            &cfg_serial,
            &tr,
            binned.clone(),
            Some(&te),
            GradientEngine::native(),
        )
        .unwrap();
        // drive the serial core; replay the same trees into fused cores
        let mut rng = Rng::new(13);
        let mut trees = Vec::new();
        for _ in 0..10 {
            let s = serial.snapshot();
            let tree = crate::tree::build_tree(
                &binned, &s.rows, &s.grad, &s.hess, &cfg_serial.tree, &mut rng,
            );
            trees.push(tree.clone());
            serial.apply_tree(tree, s.version).unwrap();
        }
        for pool in [crate::util::PoolMode::Persistent, crate::util::PoolMode::Scoped] {
            for threads in [1usize, 2, 4, 8] {
                let mut cfg_fused = cfg_serial.clone();
                cfg_fused.target = TargetMode::Fused;
                cfg_fused.score_threads = threads;
                cfg_fused.pool = pool;
                let mut fused = ServerCore::new(
                    &cfg_fused,
                    &tr,
                    binned.clone(),
                    Some(&te),
                    GradientEngine::native(),
                )
                .unwrap();
                for tree in &trees {
                    let s = fused.snapshot();
                    // identical state ⇒ identical published targets ⇒ the
                    // serial core's trees are exactly what workers would build
                    let out = fused.apply_tree(tree.clone(), s.version).unwrap();
                    assert!(out.accepted);
                }
                let at = format!("threads={threads} pool={}", pool.as_str());
                assert_eq!(fused.f, serial.f, "train F diverged ({at})");
                let sf = fused.snapshot();
                let ss = serial.snapshot();
                assert_eq!(sf.version, ss.version);
                assert_eq!(*sf.rows, *ss.rows, "sampled rows diverged ({at})");
                assert_eq!(*sf.grad, *ss.grad, "targets diverged ({at})");
                assert_eq!(*sf.hess, *ss.hess, "hessians diverged ({at})");
                let curves = |c: &crate::metrics::LossCurve| {
                    c.points
                        .iter()
                        .map(|p| (p.n_trees, p.train_loss, p.test_loss, p.test_error))
                        .collect::<Vec<_>>()
                };
                assert_eq!(
                    curves(&fused.curve),
                    curves(&serial.curve),
                    "loss curves diverged ({at})"
                );
                assert_eq!(fused.staleness.samples, serial.staleness.samples);
                assert_eq!(fused.staleness.rejected, serial.staleness.rejected);
            }
        }
    }

    #[test]
    fn sharded_core_matches_single_shard_and_composes_versions() {
        // the server-level route: ps_shards=3 must reproduce the default
        // single-shard core bit for bit, and every publish must advance
        // all shard cells so the composed version equals the counter
        // (the exhaustive matrix lives in tests/test_sharded_ps.rs)
        let ds = synthetic::realsim_like(2_600, 64);
        let cfg = mini_cfg(6);
        let mut single = core_on(&ds, &cfg);
        let mut cfg_sharded = cfg.clone();
        cfg_sharded.ps_shards = 3;
        cfg_sharded.score_threads = 2;
        cfg_sharded.pool = crate::util::PoolMode::Persistent;
        let mut sharded = core_on(&ds, &cfg_sharded);
        assert_eq!(sharded.row_partition().n_shards(), 3);
        assert_eq!(single.row_partition().n_shards(), 1);
        let mut rng = Rng::new(21);
        for _ in 0..6 {
            let s = single.snapshot();
            let tree = crate::tree::build_tree(
                &single.binned.clone(),
                &s.rows,
                &s.grad,
                &s.hess,
                &cfg.tree,
                &mut rng,
            );
            single.apply_tree(tree.clone(), s.version).unwrap();
            sharded
                .apply_tree(tree, sharded.snapshot().version)
                .unwrap();
        }
        assert_eq!(sharded.f, single.f, "sharded F diverged");
        let (a, b) = (sharded.snapshot(), single.snapshot());
        assert_eq!(a.version, b.version);
        assert_eq!(*a.rows, *b.rows, "sampled rows diverged");
        assert_eq!(*a.grad, *b.grad, "targets diverged");
        assert_eq!(*a.hess, *b.hess, "hessians diverged");
        // every cell advanced with the counter; composition is exact
        let sv = sharded.shard_versions();
        for shard in 0..sv.n_shards() {
            assert_eq!(sv.shard_version(shard), 6);
        }
        assert_eq!(sv.composed(), 6);
    }

    #[test]
    fn persistent_pool_survives_a_long_accept_stream() {
        // pool lifecycle at the server level: one ScorePool serves 120
        // accepted trees (120 fused passes + 120 held-out updates) and the
        // final state matches a scoped-mode twin bit for bit
        let ds = synthetic::realsim_like(1_400, 63);
        let mut rng0 = Rng::new(5);
        let (tr, te) = ds.split(0.25, &mut rng0);
        let binned = Arc::new(BinnedDataset::from_dataset(&tr, 16).unwrap());
        let mut cfg = mini_cfg(120);
        cfg.tree.max_leaves = 4;
        cfg.eval_every = 30;
        cfg.score_threads = 2;
        cfg.pool = crate::util::PoolMode::Persistent;
        let mut cfg_scoped = cfg.clone();
        cfg_scoped.pool = crate::util::PoolMode::Scoped;
        let mut a = ServerCore::new(&cfg, &tr, binned.clone(), Some(&te), GradientEngine::native())
            .unwrap();
        let mut b = ServerCore::new(
            &cfg_scoped,
            &tr,
            binned.clone(),
            Some(&te),
            GradientEngine::native(),
        )
        .unwrap();
        let mut rng = Rng::new(11);
        for _ in 0..120 {
            let s = a.snapshot();
            let tree =
                crate::tree::build_tree(&binned, &s.rows, &s.grad, &s.hess, &cfg.tree, &mut rng);
            a.apply_tree(tree.clone(), s.version).unwrap();
            b.apply_tree(tree, b.snapshot().version).unwrap();
        }
        assert_eq!(a.n_trees(), 120);
        assert_eq!(a.f, b.f, "persistent and scoped pools diverged");
        // scratch recycling survived the whole stream: ≤ one per worker
        assert!(a.score_pool.allocated() <= 2, "allocated {}", a.score_pool.allocated());
    }

    #[test]
    fn fused_newton_mode_uses_curvature_hessian() {
        let ds = synthetic::realsim_like(400, 62);
        let mut cfg = mini_cfg(4);
        cfg.grad_mode = GradMode::Newton;
        cfg.score_threads = 2;
        let mut core = core_on(&ds, &cfg);
        let s0 = core.snapshot();
        let mut rng = Rng::new(8);
        let tree = crate::tree::build_tree(
            &core.binned.clone(),
            &s0.rows,
            &s0.grad,
            &s0.hess,
            &cfg.tree,
            &mut rng,
        );
        core.apply_tree(tree, s0.version).unwrap();
        let s = core.snapshot();
        // Newton hess is w·4p(1-p) < w for all finite margins
        for &r in s.rows.iter().take(20) {
            let h = s.hess[r as usize];
            assert!(h > 0.0 && h < 1.2 / 0.9, "h={h}");
        }
    }

    #[test]
    fn adaptive_step_shrinks_with_staleness_and_matches_fixed_when_fresh() {
        use crate::config::StepMode;
        let ds = synthetic::realsim_like(400, 71);
        let cfg_fixed = mini_cfg(6);
        let mut cfg_adaptive = cfg_fixed.clone();
        cfg_adaptive.step = StepMode::Adaptive;
        let mut fixed = core_on(&ds, &cfg_fixed);
        let mut adaptive = core_on(&ds, &cfg_adaptive);
        let mut rng = Rng::new(5);
        // all-fresh pushes: τ=0, so v/(1+0) == v and the two cores are
        // bit-identical (satellite 2's anchor at the unit level)
        for _ in 0..4 {
            let s = fixed.snapshot();
            let tree = crate::tree::build_tree(
                &fixed.binned.clone(),
                &s.rows,
                &s.grad,
                &s.hess,
                &cfg_fixed.tree,
                &mut rng,
            );
            fixed.apply_tree(tree.clone(), s.version).unwrap();
            adaptive.apply_tree(tree, adaptive.snapshot().version).unwrap();
        }
        assert_eq!(adaptive.f, fixed.f, "adaptive diverged from fixed at τ=0");
        assert_eq!(adaptive.steps.samples, fixed.steps.samples);
        assert_eq!(adaptive.steps.samples, vec![0.3f32; 4]);
        // now a stale push: based_on 0 at version 4 ⇒ τ=4 ⇒ v_eff = 0.3/5
        let s = adaptive.snapshot();
        let tree = crate::tree::build_tree(
            &adaptive.binned.clone(),
            &s.rows,
            &s.grad,
            &s.hess,
            &cfg_adaptive.tree,
            &mut rng,
        );
        let out = adaptive.apply_tree(tree.clone(), 0).unwrap();
        assert!(out.accepted);
        assert_eq!(out.staleness, 4);
        assert_eq!(*adaptive.steps.samples.last().unwrap(), 0.3 / 5.0);
        // the fixed core applies the same stale push at full v
        fixed.apply_tree(tree, 0).unwrap();
        assert_eq!(*fixed.steps.samples.last().unwrap(), 0.3);
        assert_ne!(adaptive.f, fixed.f, "stale push should now differ");
        // the forest records the shrunken per-tree scale
        assert_eq!(adaptive.forest.trees.last().unwrap().0, 0.3 / 5.0);
    }

    #[test]
    fn replay_tree_with_reproduces_an_adaptive_run_bitwise() {
        use crate::config::StepMode;
        let ds = synthetic::realsim_like(500, 73);
        let mut cfg = mini_cfg(5);
        cfg.step = StepMode::Adaptive;
        let mut live = core_on(&ds, &cfg);
        let mut rng = Rng::new(17);
        // drive with artificial staleness: every push claims based_on 0
        for _ in 0..5 {
            let s = live.snapshot();
            let tree = crate::tree::build_tree(
                &live.binned.clone(),
                &s.rows,
                &s.grad,
                &s.hess,
                &cfg.tree,
                &mut rng,
            );
            live.apply_tree(tree, 0).unwrap();
        }
        // restore path: replay each tree at its recorded per-tree scale
        let mut replayed = core_on(&ds, &cfg);
        for (v, tree) in live.forest.trees.iter() {
            replayed.replay_tree_with(tree.clone(), *v).unwrap();
        }
        assert_eq!(replayed.f, live.f, "replayed F diverged");
        assert_eq!(replayed.steps.samples, live.steps.samples);
        let lc: Vec<f64> = live.curve.points.iter().map(|p| p.train_loss).collect();
        let rc: Vec<f64> = replayed.curve.points.iter().map(|p| p.train_loss).collect();
        assert_eq!(lc, rc, "loss curves diverged");
    }

    #[test]
    fn squared_loss_core_uses_mean_base_and_descends() {
        use crate::loss::LossKind;
        let ds = synthetic::regression_like(500, 81);
        let mut cfg = mini_cfg(10);
        cfg.loss = LossKind::Squared;
        let binned = Arc::new(BinnedDataset::from_dataset(&ds, cfg.max_bins).unwrap());
        let mut core = ServerCore::new(
            &cfg,
            &ds,
            binned,
            None,
            GradientEngine::native_for(crate::loss::ScalarLoss::Squared),
        )
        .unwrap();
        let mean = ds.y.iter().map(|&y| y as f64).sum::<f64>() / ds.n_rows() as f64;
        assert!((core.f[0] as f64 - mean).abs() < 1e-4, "base is not the label mean");
        let mut rng = Rng::new(19);
        for _ in 0..10 {
            let s = core.snapshot();
            let tree = crate::tree::build_tree(
                &core.binned.clone(),
                &s.rows,
                &s.grad,
                &s.hess,
                &cfg.tree,
                &mut rng,
            );
            core.apply_tree(tree, s.version).unwrap();
        }
        let first = core.curve.points.first().unwrap().train_loss;
        let last = core.curve.points.last().unwrap().train_loss;
        assert!(last < first * 0.98, "squared loss did not descend: {first} -> {last}");
    }

    #[test]
    fn engine_loss_mismatch_is_refused_by_name() {
        use crate::loss::LossKind;
        let ds = synthetic::regression_like(120, 82);
        let mut cfg = mini_cfg(2);
        cfg.loss = LossKind::Squared;
        let binned = Arc::new(BinnedDataset::from_dataset(&ds, cfg.max_bins).unwrap());
        let err = ServerCore::new(&cfg, &ds, binned, None, GradientEngine::native())
            .unwrap_err()
            .to_string();
        assert!(err.contains("loss=squared"), "{err}");
        assert!(err.contains("auto_for"), "{err}");
    }

    #[test]
    fn multiclass_core_lands_k_trees_per_round_and_descends() {
        use crate::loss::LossKind;
        let k = 3usize;
        let ds = synthetic::multiclass_like(600, k, 91);
        let mut rng0 = Rng::new(1);
        let (tr, te) = ds.split(0.25, &mut rng0);
        let mut cfg = mini_cfg(6);
        cfg.loss = LossKind::Multiclass;
        cfg.n_classes = k;
        let binned = Arc::new(BinnedDataset::from_dataset(&tr, cfg.max_bins).unwrap());
        let mut core =
            ServerCore::new(&cfg, &tr, binned.clone(), Some(&te), GradientEngine::native())
                .unwrap();
        // uniform softmax at init: train loss starts at ln K
        let p0 = core.curve.points.first().unwrap();
        assert!((p0.train_loss - (k as f64).ln()).abs() < 1e-5, "{}", p0.train_loss);
        let mut rng = Rng::new(23);
        for round in 0..6 {
            let s = core.snapshot();
            assert_eq!(s.grad.len(), tr.n_rows(), "structure target is per-row");
            let tree = crate::tree::build_tree(
                &binned, &s.rows, &s.grad, &s.hess, &cfg.tree, &mut rng,
            );
            let out = core.apply_tree(tree, s.version).unwrap();
            assert!(out.accepted);
            assert_eq!(out.n_trees, round + 1, "rounds, not raw trees");
            assert_eq!(core.forest.n_trees(), (round + 1) * k, "K class trees per round");
        }
        let first = core.curve.points.first().unwrap().train_loss;
        let last = core.curve.points.last().unwrap().train_loss;
        assert!(last < first - 0.02, "softmax loss did not descend: {first} -> {last}");
        // held-out error is a real argmax rate in [0, 1]
        let te_err = core.curve.points.last().unwrap().test_error;
        assert!((0.0..=1.0).contains(&te_err), "test_error={te_err}");
    }

    #[test]
    fn multiclass_replay_round_is_bit_identical() {
        use crate::loss::LossKind;
        let k = 3usize;
        let ds = synthetic::multiclass_like(400, k, 93);
        let mut cfg = mini_cfg(4);
        cfg.loss = LossKind::Multiclass;
        cfg.n_classes = k;
        let mut live = core_on(&ds, &cfg);
        let mut rng = Rng::new(29);
        for _ in 0..4 {
            let s = live.snapshot();
            let tree = crate::tree::build_tree(
                &live.binned.clone(),
                &s.rows,
                &s.grad,
                &s.hess,
                &cfg.tree,
                &mut rng,
            );
            live.apply_tree(tree, s.version).unwrap();
        }
        let mut replayed = core_on(&ds, &cfg);
        for round in live.forest.trees.chunks(k) {
            let v = round[0].0;
            let trees: Vec<Tree> = round.iter().map(|(_, t)| t.clone()).collect();
            replayed.replay_round(trees, v).unwrap();
        }
        assert_eq!(replayed.f, live.f, "replayed multiclass F diverged");
        assert_eq!(replayed.n_trees(), live.n_trees());
        let lc: Vec<f64> = live.curve.points.iter().map(|p| p.train_loss).collect();
        let rc: Vec<f64> = replayed.curve.points.iter().map(|p| p.train_loss).collect();
        assert_eq!(lc, rc, "multiclass loss curves diverged");
    }

    #[test]
    fn multiclass_rejects_labels_outside_the_class_range() {
        use crate::loss::LossKind;
        let mut ds = synthetic::multiclass_like(100, 3, 95);
        ds.y[7] = 5.0; // out of [0, 3)
        let mut cfg = mini_cfg(2);
        cfg.loss = LossKind::Multiclass;
        cfg.n_classes = 3;
        let binned = Arc::new(BinnedDataset::from_dataset(&ds, cfg.max_bins).unwrap());
        let err = ServerCore::new(&cfg, &ds, binned, None, GradientEngine::native())
            .unwrap_err()
            .to_string();
        assert!(err.contains("row 7"), "{err}");
        assert!(err.contains("[0, 3)"), "{err}");
    }

    #[test]
    fn training_loss_descends_serially() {
        let ds = synthetic::realsim_like(400, 5);
        let cfg = mini_cfg(15);
        let mut core = core_on(&ds, &cfg);
        let mut rng = Rng::new(3);
        for _ in 0..15 {
            let s = core.snapshot();
            let tree = crate::tree::build_tree(
                &core.binned.clone(),
                &s.rows,
                &s.grad,
                &s.hess,
                &cfg.tree,
                &mut rng,
            );
            core.apply_tree(tree, s.version).unwrap();
        }
        let first = core.curve.points.first().unwrap().train_loss;
        let last = core.curve.points.last().unwrap().train_loss;
        assert!(
            last < first - 0.05,
            "loss did not descend: {first} -> {last}"
        );
    }
}
