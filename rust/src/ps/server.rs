//! Server side of the PS: state machine + shared board.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

use anyhow::Result;

use crate::config::{GradMode, TrainConfig};
use crate::data::sparse::CsrMatrix;
use crate::data::{BinnedDataset, Dataset};
use crate::forest::score::{self, ScoreMode, ScratchPool};
use crate::forest::Forest;
use crate::metrics::{CurvePoint, LossCurve, StalenessStats};
use crate::runtime::GradientEngine;
use crate::sampling::BernoulliSampler;
use crate::tree::{FlatTree, Tree};
use crate::util::timer::PhaseTimer;
use crate::util::{Rng, Stopwatch};

use super::messages::TargetSnapshot;

/// The shared pull/push surface between server and workers.
///
/// Publishing is an Arc pointer swap under a short write lock; pulling is
/// a pointer clone under a read lock — workers never copy target vectors.
#[derive(Debug)]
pub struct Board {
    snapshot: RwLock<Arc<TargetSnapshot>>,
    version: AtomicU64,
    shutdown: AtomicBool,
}

impl Board {
    pub fn new() -> Board {
        Board {
            snapshot: RwLock::new(Arc::new(TargetSnapshot::empty())),
            version: AtomicU64::new(0),
            shutdown: AtomicBool::new(false),
        }
    }

    /// Publish a new target version (server only).
    pub fn publish(&self, s: TargetSnapshot) {
        let v = s.version;
        *self.snapshot.write().unwrap() = Arc::new(s);
        self.version.store(v, Ordering::Release);
    }

    /// Pull the current target (workers). O(1).
    pub fn pull(&self) -> Arc<TargetSnapshot> {
        self.snapshot.read().unwrap().clone()
    }

    /// Latest published version without taking the lock.
    pub fn version(&self) -> u64 {
        self.version.load(Ordering::Acquire)
    }

    pub fn request_shutdown(&self) {
        self.shutdown.store(true, Ordering::Release);
    }

    pub fn is_shutdown(&self) -> bool {
        self.shutdown.load(Ordering::Acquire)
    }
}

impl Default for Board {
    fn default() -> Self {
        Self::new()
    }
}

/// Held-out evaluation state (margins updated incrementally per tree).
struct TestSet {
    x: CsrMatrix,
    y: Vec<f32>,
    w: Vec<f32>,
    f: Vec<f32>,
}

/// Outcome of applying one pushed tree.
#[derive(Debug, Clone, Copy)]
pub struct ApplyOutcome {
    /// Realised delay τ = version at apply − version pulled.
    pub staleness: u64,
    /// False if the bounded-staleness filter dropped the push.
    pub accepted: bool,
    /// Trees accepted so far.
    pub n_trees: usize,
}

/// The server state machine of Algorithm 3. Owns everything on the
/// produce-target path; drives the gradient engine (AOT/PJRT when
/// artifacts are present). Not `Send` (PJRT handles) — lives on the
/// thread that runs the accept loop.
pub struct ServerCore {
    cfg: TrainConfig,
    binned: Arc<BinnedDataset>,
    train_y: Vec<f32>,
    train_m: Vec<f32>,
    engine: GradientEngine,
    sampler: BernoulliSampler,
    rng: Rng,
    /// Current prediction vector **F** over training rows.
    f: Vec<f32>,
    /// Pooled scoring scratch for the blocked F-update (step 2) — row-id
    /// blocks + partition stacks recycled across every accepted tree.
    score_pool: ScratchPool,
    pub forest: Forest,
    test: Option<TestSet>,
    pub curve: LossCurve,
    pub staleness: StalenessStats,
    pub timer: PhaseTimer,
    clock: Stopwatch,
    current: TargetSnapshot,
}

impl ServerCore {
    /// Initialise per Algorithm 3's server prologue: constant tree at the
    /// weighted mean label, then compute and hold `L'^0_random`.
    pub fn new(
        cfg: &TrainConfig,
        train: &Dataset,
        binned: Arc<BinnedDataset>,
        test: Option<&Dataset>,
        engine: GradientEngine,
    ) -> Result<ServerCore> {
        cfg.validate()?;
        let base = Forest::base_from_positive_rate(train.positive_rate());
        let forest = Forest::new(base);
        let f = vec![base; train.n_rows()];
        let sampler = BernoulliSampler::uniform(train, cfg.sampling_rate);
        let rng = Rng::new(cfg.seed ^ SERVER_SEED_SALT);
        let test = test.map(|t| TestSet {
            f: vec![base; t.n_rows()],
            y: t.y.clone(),
            w: t.m.clone(),
            x: t.x.clone(),
        });
        let mut core = ServerCore {
            cfg: cfg.clone(),
            binned,
            train_y: train.y.clone(),
            train_m: train.m.clone(),
            engine,
            sampler,
            rng,
            f,
            score_pool: ScratchPool::new(),
            forest,
            test,
            curve: LossCurve::default(),
            staleness: StalenessStats::default(),
            timer: PhaseTimer::new(),
            clock: Stopwatch::new(),
            current: TargetSnapshot::empty(),
        };
        core.produce_target(0)?;
        core.eval_point()?; // curve point at 0 trees
        Ok(core)
    }

    /// The engine kind actually in use (logging / EXPERIMENTS.md).
    pub fn engine_kind(&self) -> crate::runtime::EngineKind {
        self.engine.kind()
    }

    /// Current target snapshot (version = #accepted trees).
    pub fn snapshot(&self) -> TargetSnapshot {
        self.current.clone()
    }

    pub fn n_trees(&self) -> usize {
        self.forest.n_trees()
    }

    /// Apply one pushed tree (Algorithm 3 server steps 1–5). Returns the
    /// outcome; on acceptance the new target has been produced and
    /// `snapshot()` reflects version j+1.
    pub fn apply_tree(&mut self, tree: Tree, based_on: u64) -> Result<ApplyOutcome> {
        let version = self.forest.n_trees() as u64;
        let tau = version.saturating_sub(based_on);
        if let Some(max_tau) = self.cfg.max_staleness {
            if tau > max_tau {
                self.staleness.record_rejected();
                return Ok(ApplyOutcome {
                    staleness: tau,
                    accepted: false,
                    n_trees: self.forest.n_trees(),
                });
            }
        }
        self.staleness.record(tau);

        // step 2: F^j = F^{j-1} + v * Tree. The blocked SoA engine and the
        // per-row enum reference produce bit-identical F vectors (same f32
        // ops in the same per-row order); `scoring=perrow` keeps the
        // reference selectable for equivalence tests and ablation.
        let v = self.cfg.step_length;
        match self.cfg.scoring {
            ScoreMode::Flat => {
                let flat = self
                    .timer
                    .time("server/flatten_tree", || FlatTree::from_tree(&tree));
                let t0 = std::time::Instant::now();
                score::add_tree_binned(
                    &flat,
                    &self.binned,
                    v,
                    &mut self.f,
                    self.cfg.score_threads,
                    &mut self.score_pool,
                );
                self.timer.record("server/update_f", t0.elapsed());
                if let Some(test) = &mut self.test {
                    let t0 = std::time::Instant::now();
                    score::add_tree_raw(
                        &flat,
                        &test.x,
                        v,
                        &mut test.f,
                        self.cfg.score_threads,
                        &mut self.score_pool,
                    );
                    self.timer.record("server/update_f_test", t0.elapsed());
                }
            }
            ScoreMode::PerRow => {
                let t0 = std::time::Instant::now();
                for r in 0..self.f.len() {
                    self.f[r] += v * tree.predict_binned(&self.binned, r);
                }
                self.timer.record("server/update_f", t0.elapsed());
                if let Some(test) = &mut self.test {
                    let t0 = std::time::Instant::now();
                    for r in 0..test.f.len() {
                        test.f[r] += v * tree.predict_raw(&test.x, r);
                    }
                    self.timer.record("server/update_f_test", t0.elapsed());
                }
            }
        }
        self.forest.push(v, tree);

        // steps 3–5: resample, produce L'^{j+1}_random, publish
        let new_version = self.forest.n_trees() as u64;
        self.produce_target(new_version)?;

        if self.forest.n_trees() % self.cfg.eval_every == 0
            || self.forest.n_trees() == self.cfg.n_trees
        {
            self.eval_point()?;
        }
        Ok(ApplyOutcome {
            staleness: tau,
            accepted: true,
            n_trees: self.forest.n_trees(),
        })
    }

    /// Sample Q and compute the stochastic target on the sub-dataset.
    fn produce_target(&mut self, version: u64) -> Result<()> {
        let pass = self
            .timer
            .time("server/sample", || self.sampler.draw(&mut self.rng));
        let (f, y) = (&self.f, &self.train_y);
        let gh = {
            let engine = &mut self.engine;
            let timer = &mut self.timer;
            let t0 = std::time::Instant::now();
            let gh = engine.grad_hess_loss(f, y, &pass.weights)?;
            timer.record("server/produce_target", t0.elapsed());
            gh
        };
        let hess = match self.cfg.grad_mode {
            GradMode::Newton => gh.hess,
            // gradient mode: weighted-LS fit => h_i := m'_i
            GradMode::Gradient => pass.weights.clone(),
        };
        self.current = TargetSnapshot {
            version,
            grad: Arc::new(gh.grad),
            hess: Arc::new(hess),
            rows: Arc::new(pass.rows),
        };
        Ok(())
    }

    /// Record a loss-curve point (full-weight train loss + test metrics).
    fn eval_point(&mut self) -> Result<()> {
        let t0 = std::time::Instant::now();
        let (l, _e, w) = self
            .engine
            .eval_sums(&self.f, &self.train_y, &self.train_m)?;
        let train_loss = if w > 0.0 { l / w } else { 0.0 };
        let (test_loss, test_error) = if let Some(test) = &self.test {
            let (tl, te, tw) = self.engine.eval_sums(&test.f, &test.y, &test.w)?;
            if tw > 0.0 {
                (tl / tw, te / tw)
            } else {
                (f64::NAN, f64::NAN)
            }
        } else {
            (f64::NAN, f64::NAN)
        };
        self.timer.record("server/eval", t0.elapsed());
        self.curve.push(CurvePoint {
            n_trees: self.forest.n_trees(),
            train_loss,
            test_loss,
            test_error,
            wall_secs: self.clock.elapsed(),
        });
        Ok(())
    }
}

/// Salt separating the server's sampling stream from worker streams that
/// share the same user seed.
const SERVER_SEED_SALT: u64 = 0x9e37_79b9_7f4a_7c15;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    fn mini_cfg(n_trees: usize) -> TrainConfig {
        let mut cfg = TrainConfig::default();
        cfg.n_trees = n_trees;
        cfg.step_length = 0.3;
        cfg.sampling_rate = 0.9;
        cfg.workers = 1;
        cfg.tree.max_leaves = 8;
        cfg.tree.feature_rate = 1.0;
        cfg.eval_every = 1;
        cfg
    }

    fn core_on(ds: &Dataset, cfg: &TrainConfig) -> ServerCore {
        let binned = Arc::new(BinnedDataset::from_dataset(ds, cfg.max_bins).unwrap());
        ServerCore::new(cfg, ds, binned, None, GradientEngine::native()).unwrap()
    }

    #[test]
    fn init_publishes_version_zero_with_sampled_target() {
        let ds = synthetic::realsim_like(300, 1);
        let cfg = mini_cfg(5);
        let core = core_on(&ds, &cfg);
        let s = core.snapshot();
        assert_eq!(s.version, 0);
        assert!(s.n_sampled() > 200); // rate 0.9
        assert_eq!(s.grad.len(), 300);
        assert_eq!(core.curve.points.len(), 1); // initial eval point
    }

    #[test]
    fn apply_tree_advances_version_and_records_staleness() {
        let ds = synthetic::realsim_like(200, 2);
        let cfg = mini_cfg(5);
        let mut core = core_on(&ds, &cfg);
        let s = core.snapshot();
        let mut rng = Rng::new(1);
        let tree = crate::tree::build_tree(
            &core.binned.clone(),
            &s.rows,
            &s.grad,
            &s.hess,
            &cfg.tree,
            &mut rng,
        );
        let out = core.apply_tree(tree, s.version).unwrap();
        assert!(out.accepted);
        assert_eq!(out.staleness, 0);
        assert_eq!(core.snapshot().version, 1);
        assert_eq!(core.n_trees(), 1);
    }

    #[test]
    fn bounded_staleness_rejects_old_pushes() {
        let ds = synthetic::realsim_like(200, 3);
        let mut cfg = mini_cfg(10);
        cfg.max_staleness = Some(0);
        let mut core = core_on(&ds, &cfg);
        let s0 = core.snapshot();
        let mut rng = Rng::new(2);
        let t1 = crate::tree::build_tree(&core.binned.clone(), &s0.rows, &s0.grad, &s0.hess, &cfg.tree, &mut rng);
        let t2 = t1.clone();
        core.apply_tree(t1, 0).unwrap();
        // second push still based on version 0: tau = 1 > max 0 => rejected
        let out = core.apply_tree(t2, 0).unwrap();
        assert!(!out.accepted);
        assert_eq!(core.n_trees(), 1);
        assert_eq!(core.staleness.rejected, 1);
    }

    #[test]
    fn gradient_mode_uses_weights_as_hessian() {
        let ds = synthetic::realsim_like(100, 4);
        let mut cfg = mini_cfg(3);
        cfg.grad_mode = GradMode::Gradient;
        let core = core_on(&ds, &cfg);
        let s = core.snapshot();
        for &r in s.rows.iter().take(10) {
            // hess equals the sampling weight (1/0.9 for selected unit rows)
            assert!((s.hess[r as usize] - 1.0 / 0.9).abs() < 1e-3);
        }
    }

    #[test]
    fn flat_and_per_row_scoring_produce_identical_state() {
        // the acceptance bar for the blocked engine: both scorers yield
        // the same F vector, hence bit-identical targets and loss curves
        // 2600 rows: the train split exceeds 2 * ROW_BLOCK, so the flat
        // core takes the threaded (block-claiming) path
        let ds = synthetic::realsim_like(2_600, 6);
        let mut rng0 = Rng::new(7);
        let (tr, te) = ds.split(0.25, &mut rng0);
        let binned = Arc::new(BinnedDataset::from_dataset(&tr, 16).unwrap());
        let mut cfg_flat = mini_cfg(8);
        cfg_flat.scoring = crate::forest::ScoreMode::Flat;
        cfg_flat.score_threads = 3;
        let mut cfg_ref = cfg_flat.clone();
        cfg_ref.scoring = crate::forest::ScoreMode::PerRow;
        cfg_ref.score_threads = 1;
        let mut core_a =
            ServerCore::new(&cfg_flat, &tr, binned.clone(), Some(&te), GradientEngine::native())
                .unwrap();
        let mut core_b =
            ServerCore::new(&cfg_ref, &tr, binned.clone(), Some(&te), GradientEngine::native())
                .unwrap();
        let mut rng = Rng::new(9);
        for _ in 0..8 {
            let s = core_a.snapshot();
            let tree = crate::tree::build_tree(
                &binned, &s.rows, &s.grad, &s.hess, &cfg_flat.tree, &mut rng,
            );
            core_a.apply_tree(tree.clone(), s.version).unwrap();
            core_b.apply_tree(tree, core_b.snapshot().version).unwrap();
        }
        assert_eq!(core_a.f, core_b.f, "train F vectors diverged");
        let la: Vec<f64> = core_a.curve.points.iter().map(|p| p.train_loss).collect();
        let lb: Vec<f64> = core_b.curve.points.iter().map(|p| p.train_loss).collect();
        assert_eq!(la, lb, "loss curves diverged");
        let ta: Vec<f64> = core_a.curve.points.iter().map(|p| p.test_loss).collect();
        let tb: Vec<f64> = core_b.curve.points.iter().map(|p| p.test_loss).collect();
        assert_eq!(ta, tb, "test curves diverged");
        // pooled scratch reached steady state: at most score_threads buffers
        assert!(core_a.score_pool.allocated() <= 3);
    }

    #[test]
    fn training_loss_descends_serially() {
        let ds = synthetic::realsim_like(400, 5);
        let cfg = mini_cfg(15);
        let mut core = core_on(&ds, &cfg);
        let mut rng = Rng::new(3);
        for _ in 0..15 {
            let s = core.snapshot();
            let tree = crate::tree::build_tree(&core.binned.clone(), &s.rows, &s.grad, &s.hess, &cfg.tree, &mut rng);
            core.apply_tree(tree, s.version).unwrap();
        }
        let first = core.curve.points.first().unwrap().train_loss;
        let last = core.curve.points.last().unwrap().train_loss;
        assert!(
            last < first - 0.05,
            "loss did not descend: {first} -> {last}"
        );
    }
}
