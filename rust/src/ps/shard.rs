//! The fused row-sharded accept pipeline — Algorithm 3's server steps
//! 2–4 collapsed into **one pass over the training rows** per accepted
//! tree.
//!
//! The serial accept path sweeps all n rows four times per tree: score
//! the tree into **F** (step 2), draw the next Bernoulli sample (step
//! 3), compute grad/hess for the new target (step 4), and — on eval
//! trees — accumulate loss/error sums. Each sweep re-streams the same
//! vectors through the cache, and all four sit on the accept loop's
//! critical path, bounding accepted trees/sec at high worker counts.
//!
//! [`fused_accept_pass`] partitions the rows into contiguous
//! whole-block shards (multiples of [`ROW_BLOCK`]) and runs all four
//! stages block by block inside each shard: a block's margins are
//! updated by the flattened tree, and while they are still
//! cache-resident the block is sampled, its target rows get grad/hess
//! on the fresh margins, and its eval partial is taken. Shards execute
//! in parallel on up to `score_threads` workers obtained from the
//! server's [`crate::util::Executor`] — the parked server-lifetime
//! [`crate::util::ScorePool`] under `pool=persistent` (no per-tree
//! thread spawn/join), per-pass scoped spawns under `pool=scoped` —
//! each shard owning disjoint `&mut` slices of F/weights/grad/hess, so
//! no synchronisation exists inside the pass.
//!
//! **Why fused ≡ serial, bit for bit, at every shard count:**
//!
//! * *F-update* — the per-shard block loop applies
//!   [`score::add_block_binned`], the exact kernel `target=serial`'s
//!   blocked scorer applies to the same blocks; per-row f32 ops are
//!   identical regardless of which thread touches a block.
//! * *Sampling* — every row's draw is a [`crate::util::CounterRng`] keyed on
//!   `(seed, version, row)` (see `sampling/bernoulli.rs`), a pure
//!   function of the key: any contiguous sharding reproduces the
//!   sequential row set exactly.
//! * *Targets* — grad/hess per row are the configured scalar loss's
//!   `grad_hess_at` ([`crate::loss::ScalarLoss`]) on the updated
//!   margin, the same expression the whole-vector engine compiles; rows
//!   are independent, so sharding cannot reorder anything.
//! * *Eval* — f64 loss/error partials are taken per global
//!   [`ROW_BLOCK`] (each partial starts from 0.0) and folded in block
//!   order after the join ([`logistic::fold_eval_blocks`]); the serial
//!   path reduces through `logistic::eval_sums_blocked` with the same
//!   block size, so the two f64 addition sequences are identical.
//!
//! The AOT gradient engine is neither `Send` nor shard-wise
//! (`GradientEngine::supports_ranges`), so under AOT the server runs
//! this pass with `compute_target`/`want_eval` off — scoring and
//! sampling stay fused and sharded — and falls back to whole-vector
//! engine calls for the target and eval, the same calls the serial
//! path makes.

use std::sync::Mutex;

use crate::data::BinnedDataset;
use crate::forest::score::{self, ScoreScratch, ScratchPool, ROW_BLOCK};
use crate::loss::{logistic, ScalarLoss};
use crate::sampling::{BernoulliSampler, SampleKey};
use crate::tree::FlatTree;
use crate::util::Executor;

/// Which accept pipeline the server runs per accepted tree (config key
/// `target`; see DESIGN.md §11).
///
/// ```
/// use asgbdt::ps::TargetMode;
/// assert_eq!(TargetMode::parse("fused").unwrap(), TargetMode::Fused);
/// assert_eq!(TargetMode::Serial.as_str(), "serial");
/// assert_eq!(TargetMode::default(), TargetMode::Fused);
/// ```
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum TargetMode {
    /// One fused sharded pass: F-update + sample + grad/hess + eval
    /// partials per row block (this module).
    #[default]
    Fused,
    /// The reference path: separate full-row sweeps for scoring,
    /// sampling, target production and eval — kept selectable for the
    /// equivalence tests and the accept-path ablation.
    Serial,
}

impl TargetMode {
    /// Parse the `target=` config/CLI value.
    pub fn parse(s: &str) -> anyhow::Result<TargetMode> {
        match s {
            "fused" => Ok(TargetMode::Fused),
            "serial" => Ok(TargetMode::Serial),
            other => anyhow::bail!("unknown target mode '{other}' (fused|serial)"),
        }
    }

    /// The config/CLI spelling of this mode.
    pub fn as_str(&self) -> &'static str {
        match self {
            TargetMode::Fused => "fused",
            TargetMode::Serial => "serial",
        }
    }
}

/// Read-only inputs of one fused accept pass (bundled so the per-shard
/// worker borrows one `Sync` view instead of nine arguments).
pub struct AcceptInputs<'a> {
    /// The accepted tree, flattened; `None` skips the F-update (the
    /// server's init pass, where only sampling/target/eval run).
    pub flat: Option<&'a FlatTree>,
    /// The training rows in binned form (what the tree routes on).
    pub binned: &'a BinnedDataset,
    /// Step length v scaling the tree into F.
    pub v: f32,
    /// Training labels, full length.
    pub y: &'a [f32],
    /// Full multiplicities m_i (eval weights).
    pub m: &'a [f32],
    /// The keyed Bernoulli sampler (step 3).
    pub sampler: &'a BernoulliSampler,
    /// Key of the sampling pass being produced (version = j + 1).
    pub key: SampleKey,
    /// The scalar loss whose per-row `(w·l', w·l'')` expression and eval
    /// sums the shard kernel compiles — the same dispatch value the
    /// whole-vector engine holds, so fused and fallback paths agree
    /// bitwise per loss.
    pub loss: ScalarLoss,
    /// Compute grad/hess in-shard (native engine); off under AOT, where
    /// the server falls back to a whole-vector engine call.
    pub compute_target: bool,
    /// Accumulate per-block eval partials (only on eval trees, native).
    pub want_eval: bool,
}

/// Output of one fused accept pass. `weights` is full-length, zero
/// outside the sampled support; `rows` is the support, ascending;
/// `grad`/`hess` are full-length when `compute_target` was set and
/// empty otherwise (the AOT fallback produces them on the engine).
pub struct FusedResult {
    /// Sampled weights m'_i, full-length.
    pub weights: Vec<f32>,
    /// Gradient target (empty unless `compute_target`).
    pub grad: Vec<f32>,
    /// Hessian target (empty unless `compute_target`).
    pub hess: Vec<f32>,
    /// The sampled support, ascending.
    pub rows: Vec<u32>,
    /// (Σloss, Σerr, Σw) over full multiplicities on the updated
    /// margins; `Some` iff `want_eval` was set.
    pub eval: Option<(f64, f64, f64)>,
}

/// One shard's disjoint mutable views (rows `[start_row, start_row +
/// f.len())`, whole [`ROW_BLOCK`]s except possibly the global tail).
///
/// `pub(super)` so the sharded parameter server (`ps/sharded.rs`) can
/// hand each *server shard's* owned slices through the identical kernel
/// — sharing the struct is part of the bit-identity argument.
pub(super) struct ShardTask<'a> {
    pub(super) start_row: usize,
    pub(super) f: &'a mut [f32],
    pub(super) weights: &'a mut [f32],
    pub(super) grad: &'a mut [f32],
    pub(super) hess: &'a mut [f32],
    /// Per-block eval partials, one slot per block of this shard (empty
    /// when eval is off).
    pub(super) eval: &'a mut [(f64, f64, f64)],
}

/// The per-shard kernel: block loop running score → sample → target →
/// eval on each [`ROW_BLOCK`]. Returns the shard's sampled rows
/// (ascending global ids). `pub(super)`: `ps/sharded.rs` runs the same
/// kernel over its own row partition.
pub(super) fn run_shard(
    inp: &AcceptInputs<'_>,
    task: ShardTask<'_>,
    scratch: &mut ScoreScratch,
) -> Vec<u32> {
    let ShardTask {
        start_row,
        f,
        weights,
        grad,
        hess,
        eval,
    } = task;
    let n = f.len();
    let mut rows = Vec::new();
    let mut bi = 0usize;
    let mut local = 0usize;
    while local < n {
        let end = (local + ROW_BLOCK).min(n);
        let gstart = start_row + local;
        // step 2: F_block += v * tree(rows) — the blocked scorer's kernel
        if let Some(flat) = inp.flat {
            score::add_block_binned(flat, inp.binned, inp.v, gstart, &mut f[local..end], scratch);
        }
        // steps 3–4 on the fresh margins, row by row while cache-resident
        for i in local..end {
            let r = start_row + i;
            let w = inp.sampler.draw_row(inp.key, r);
            if w > 0.0 {
                weights[i] = w;
                rows.push(r as u32);
                if inp.compute_target {
                    let (g, h) = inp.loss.grad_hess_at(f[i], inp.y[r], w);
                    grad[i] = g;
                    hess[i] = h;
                }
            }
        }
        // eval partial for this global block (full multiplicities)
        if inp.want_eval {
            let gend = start_row + end;
            eval[bi] =
                inp.loss
                    .eval_sums(&f[local..end], &inp.y[gstart..gend], &inp.m[gstart..gend]);
        }
        bi += 1;
        local = end;
    }
    rows
}

/// Run one fused accept pass over `f`, sharded across the executor's
/// workers (at most one shard per thread of `exec`). Scratch buffers
/// come from — and return to — `pool` (the same [`ScratchPool`]
/// contract as the blocked scorer). The result is bit-identical for
/// every shard count and for both executor modes (see the module docs):
/// the shard split depends only on the thread budget, and each shard is
/// a pure function of its rows, whichever thread runs it.
pub fn fused_accept_pass(
    inp: &AcceptInputs<'_>,
    f: &mut [f32],
    exec: &Executor,
    pool: &mut ScratchPool,
) -> FusedResult {
    let n = f.len();
    assert_eq!(inp.y.len(), n);
    assert_eq!(inp.m.len(), n);
    assert_eq!(inp.sampler.n_rows(), n);
    let n_blocks = n.div_ceil(ROW_BLOCK).max(1);
    let n_shards = exec.threads().clamp(1, n_blocks);
    let mut weights = vec![0.0f32; n];
    // target vectors only materialise when computed in-shard (native);
    // the AOT fallback produces them whole-vector on the engine instead
    let target_len = if inp.compute_target { n } else { 0 };
    let mut grad = vec![0.0f32; target_len];
    let mut hess = vec![0.0f32; target_len];
    let mut eval_blocks =
        vec![(0.0f64, 0.0f64, 0.0f64); if inp.want_eval { n_blocks } else { 0 }];

    let rows = if n_shards == 1 {
        let mut scratch = pool.take();
        let rows = run_shard(
            inp,
            ShardTask {
                start_row: 0,
                f,
                weights: &mut weights,
                grad: &mut grad,
                hess: &mut hess,
                eval: &mut eval_blocks,
            },
            &mut scratch,
        );
        pool.give(scratch);
        rows
    } else {
        // carve contiguous whole-block shards (only the global tail block
        // may be short), splitting every vector into disjoint &mut views
        let per = n_blocks / n_shards;
        let rem = n_blocks % n_shards;
        let mut tasks = Vec::with_capacity(n_shards);
        let mut f_rest = f;
        let mut w_rest = weights.as_mut_slice();
        let mut g_rest = grad.as_mut_slice();
        let mut h_rest = hess.as_mut_slice();
        let mut e_rest = eval_blocks.as_mut_slice();
        let mut row0 = 0usize;
        for s in 0..n_shards {
            let blocks = per + usize::from(s < rem);
            let len = (blocks * ROW_BLOCK).min(n - row0);
            let (f_s, fr) = f_rest.split_at_mut(len);
            f_rest = fr;
            let (w_s, wr) = w_rest.split_at_mut(len);
            w_rest = wr;
            let target_len = if inp.compute_target { len } else { 0 };
            let (g_s, gr) = g_rest.split_at_mut(target_len);
            g_rest = gr;
            let (h_s, hr) = h_rest.split_at_mut(target_len);
            h_rest = hr;
            let (e_s, er) = e_rest.split_at_mut(if inp.want_eval { blocks } else { 0 });
            e_rest = er;
            tasks.push(ShardTask {
                start_row: row0,
                f: f_s,
                weights: w_s,
                grad: g_s,
                hess: h_s,
                eval: e_s,
            });
            row0 += len;
        }
        // one slot per shard: the worker with index `tid` takes task
        // `tid`, runs it with its own scratch, and parks the shard's
        // sampled rows back in its slot (slot mutexes are uncontended —
        // exactly one worker touches each)
        let slots: Vec<Mutex<(Option<ShardTask<'_>>, ScoreScratch, Vec<u32>)>> = tasks
            .into_iter()
            .map(|task| Mutex::new((Some(task), pool.take(), Vec::new())))
            .collect();
        exec.run(n_shards, &|tid| {
            let mut slot = slots[tid].lock().unwrap();
            let (task, scratch, out) = &mut *slot;
            let task = task.take().expect("shard task dispatched twice");
            *out = run_shard(inp, task, scratch);
        });
        // shards are contiguous ascending, so concatenation is ascending
        let parts: Vec<(ScoreScratch, Vec<u32>)> = slots
            .into_iter()
            .map(|slot| {
                let (_, scratch, shard_rows) = slot.into_inner().unwrap();
                (scratch, shard_rows)
            })
            .collect();
        let mut rows = Vec::with_capacity(parts.iter().map(|(_, r)| r.len()).sum());
        for (scratch, shard_rows) in parts {
            pool.give(scratch);
            rows.extend_from_slice(&shard_rows);
        }
        rows
    };

    let eval = inp
        .want_eval
        .then(|| logistic::fold_eval_blocks(&eval_blocks));
    FusedResult {
        weights,
        grad,
        hess,
        rows,
        eval,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{synthetic, Dataset};
    use crate::tree::{build_tree, TreeParams};
    use crate::util::{PoolMode, Rng};
    use std::sync::Arc;

    fn setup(n: usize, seed: u64) -> (Dataset, Arc<BinnedDataset>, FlatTree) {
        let ds = synthetic::realsim_like(n, seed);
        let b = Arc::new(BinnedDataset::from_dataset(&ds, 16).unwrap());
        let w = vec![1.0f32; n];
        let f0 = vec![0.0f32; n];
        let gh = logistic::grad_hess_loss(&f0, &ds.y, &w);
        let rows: Vec<u32> = (0..n as u32).collect();
        let params = TreeParams {
            max_leaves: 12,
            feature_rate: 0.9,
            ..Default::default()
        };
        let tree = build_tree(&b, &rows, &gh.grad, &gh.hess, &params, &mut Rng::new(seed));
        (ds, b, FlatTree::from_tree(&tree))
    }

    fn inputs<'a>(
        ds: &'a Dataset,
        b: &'a BinnedDataset,
        flat: Option<&'a FlatTree>,
        sampler: &'a BernoulliSampler,
        key: SampleKey,
        want_eval: bool,
    ) -> AcceptInputs<'a> {
        AcceptInputs {
            flat,
            binned: b,
            v: 0.2,
            y: &ds.y,
            m: &ds.m,
            sampler,
            key,
            loss: ScalarLoss::Logistic,
            compute_target: true,
            want_eval,
        }
    }

    #[test]
    fn fused_pass_matches_the_serial_recipe_bitwise() {
        // reference: the four separate sweeps the serial path performs
        let (ds, b, flat) = setup(1_900, 21);
        let n = ds.n_rows();
        let sampler = BernoulliSampler::uniform(&ds, 0.7);
        let key = SampleKey { seed: 5, version: 3 };

        let mut f_ref = vec![0.05f32; n];
        score::add_tree_binned(
            &flat,
            &b,
            0.2,
            &mut f_ref,
            &Executor::scoped(1),
            &mut ScratchPool::new(),
        );
        let pass = sampler.draw(key);
        let gh = logistic::grad_hess_loss(&f_ref, &ds.y, &pass.weights);
        let ev_ref = logistic::eval_sums_blocked(&f_ref, &ds.y, &ds.m, ROW_BLOCK);

        let inp = inputs(&ds, &b, Some(&flat), &sampler, key, true);
        for mode in [PoolMode::Persistent, PoolMode::Scoped] {
            let exec = Executor::new(mode, 3);
            let mut f = vec![0.05f32; n];
            let mut pool = ScratchPool::new();
            let out = fused_accept_pass(&inp, &mut f, &exec, &mut pool);

            assert_eq!(f, f_ref, "fused F diverged from blocked scorer ({mode:?})");
            assert_eq!(out.weights, pass.weights);
            assert_eq!(out.rows, pass.rows);
            assert_eq!(out.grad, gh.grad);
            assert_eq!(out.hess, gh.hess);
            assert_eq!(out.eval.unwrap(), ev_ref);
        }
    }

    #[test]
    fn fused_pass_matches_the_serial_recipe_for_every_scalar_loss() {
        // the same four-sweep reference, per loss kernel: whatever the
        // dispatch value, the fused pass must equal the whole-vector
        // recipe bit for bit (0/1 labels double as regression targets)
        let (ds, b, flat) = setup(1_100, 26);
        let n = ds.n_rows();
        let sampler = BernoulliSampler::uniform(&ds, 0.6);
        let key = SampleKey { seed: 8, version: 4 };
        for loss in [ScalarLoss::Squared, ScalarLoss::Huber(0.7)] {
            let mut f_ref = vec![0.1f32; n];
            score::add_tree_binned(
                &flat,
                &b,
                0.2,
                &mut f_ref,
                &Executor::scoped(1),
                &mut ScratchPool::new(),
            );
            let pass = sampler.draw(key);
            let gh = loss.grad_hess_loss(&f_ref, &ds.y, &pass.weights);
            let ev_ref = loss.eval_sums_blocked(&f_ref, &ds.y, &ds.m, ROW_BLOCK);

            let mut inp = inputs(&ds, &b, Some(&flat), &sampler, key, true);
            inp.loss = loss;
            let mut f = vec![0.1f32; n];
            let mut pool = ScratchPool::new();
            let out = fused_accept_pass(&inp, &mut f, &Executor::scoped(3), &mut pool);
            assert_eq!(f, f_ref, "{loss:?}: fused F diverged");
            assert_eq!(out.weights, pass.weights, "{loss:?}");
            assert_eq!(out.grad, gh.grad, "{loss:?}");
            assert_eq!(out.hess, gh.hess, "{loss:?}");
            assert_eq!(out.eval.unwrap(), ev_ref, "{loss:?}");
        }
    }

    #[test]
    fn fused_pass_is_shard_count_invariant() {
        let (ds, b, flat) = setup(2_300, 22);
        let n = ds.n_rows();
        let sampler = BernoulliSampler::uniform(&ds, 0.5);
        let key = SampleKey { seed: 9, version: 7 };
        let base = vec![0.1f32; n];
        let mut pool = ScratchPool::new();
        let inp = inputs(&ds, &b, Some(&flat), &sampler, key, true);
        let mut f1 = base.clone();
        let one = fused_accept_pass(&inp, &mut f1, &Executor::scoped(1), &mut pool);
        for mode in [PoolMode::Persistent, PoolMode::Scoped] {
            for threads in [2usize, 3, 8] {
                let exec = Executor::new(mode, threads);
                let mut ft = base.clone();
                let many = fused_accept_pass(&inp, &mut ft, &exec, &mut pool);
                let at = format!("{threads} shards ({mode:?})");
                assert_eq!(ft, f1, "F differs at {at}");
                assert_eq!(many.weights, one.weights, "weights differ at {at}");
                assert_eq!(many.rows, one.rows, "rows differ at {at}");
                assert_eq!(many.grad, one.grad, "grad differs at {at}");
                assert_eq!(many.hess, one.hess, "hess differs at {at}");
                assert_eq!(many.eval, one.eval, "eval sums differ at {at}");
            }
        }
    }

    #[test]
    fn init_pass_without_tree_only_samples_and_produces_target() {
        let (ds, b, _flat) = setup(600, 23);
        let sampler = BernoulliSampler::uniform(&ds, 0.8);
        let key = SampleKey { seed: 1, version: 0 };
        let base = vec![0.3f32; ds.n_rows()];
        let mut f = base.clone();
        let mut pool = ScratchPool::new();
        let inp = inputs(&ds, &b, None, &sampler, key, false);
        let out = fused_accept_pass(&inp, &mut f, &Executor::scoped(4), &mut pool);
        assert_eq!(f, base, "init pass must not touch F");
        assert!(out.eval.is_none());
        let pass = sampler.draw(key);
        assert_eq!(out.rows, pass.rows);
        let gh = logistic::grad_hess_loss(&base, &ds.y, &pass.weights);
        assert_eq!(out.grad, gh.grad);
    }

    #[test]
    fn aot_fallback_shape_skips_target_vectors_but_keeps_sampling_fused() {
        // compute_target off (AOT engines): scoring + sampling still run
        // fused and sharded; grad/hess are not materialised at all
        let (ds, b, flat) = setup(1_100, 25);
        let sampler = BernoulliSampler::uniform(&ds, 0.5);
        let key = SampleKey { seed: 3, version: 2 };
        let mut inp = inputs(&ds, &b, Some(&flat), &sampler, key, false);
        inp.compute_target = false;
        let mut f = vec![0.0f32; ds.n_rows()];
        let mut pool = ScratchPool::new();
        let out = fused_accept_pass(&inp, &mut f, &Executor::scoped(2), &mut pool);
        assert!(out.grad.is_empty() && out.hess.is_empty());
        let pass = sampler.draw(key);
        assert_eq!(out.weights, pass.weights);
        assert_eq!(out.rows, pass.rows);
        assert!(f.iter().any(|&x| x != 0.0), "F-update must still run");
    }

    #[test]
    fn scratch_pool_reaches_steady_state_across_passes() {
        let (ds, b, flat) = setup(2_100, 24);
        let sampler = BernoulliSampler::uniform(&ds, 0.6);
        for exec in [Executor::scoped(3), Executor::new(PoolMode::Persistent, 3)] {
            let mut f = vec![0.0f32; ds.n_rows()];
            let mut pool = ScratchPool::new();
            for v in 0..5 {
                let key = SampleKey { seed: 2, version: v };
                let inp = inputs(&ds, &b, Some(&flat), &sampler, key, v % 2 == 0);
                fused_accept_pass(&inp, &mut f, &exec, &mut pool);
            }
            assert!(pool.allocated() <= 3, "allocated {}", pool.allocated());
            assert_eq!(pool.idle(), pool.allocated(), "scratch leaked");
        }
    }

    #[test]
    fn target_mode_parse_roundtrip() {
        assert_eq!(TargetMode::parse("fused").unwrap(), TargetMode::Fused);
        assert_eq!(TargetMode::parse("serial").unwrap(), TargetMode::Serial);
        assert!(TargetMode::parse("split").is_err());
        for m in [TargetMode::Fused, TargetMode::Serial] {
            assert_eq!(TargetMode::parse(m.as_str()).unwrap(), m);
        }
        assert_eq!(TargetMode::default(), TargetMode::Fused);
    }
}
