//! The parameter server (Algorithm 3).
//!
//! * [`messages`] — the wire types: versioned target snapshots
//!   (`L'_random` + sampled support) flowing server → workers, tree pushes
//!   flowing workers → server.
//! * [`server`] — `ServerCore`, the server state machine: owns the forest
//!   `F(x)`, the prediction vector **F**, the gradient engine (AOT/PJRT),
//!   and the sampler; every accepted tree triggers update F → resample →
//!   produce target → publish. The F-update runs the blocked SoA scoring
//!   engine (`forest/score.rs`): each accepted tree is flattened once and
//!   applied block-wise, with pooled scratch recycled across trees.
//!   `Board` is the shared pull/push surface.
//! * [`worker`] — the worker loop: pull latest target, build a tree on the
//!   sampled sub-dataset, push. Workers are mutually blind; only the
//!   pull/build/push order *within* one worker is serialised, exactly the
//!   paper's asynchrony model. Each worker owns a
//!   [`crate::tree::HistogramPool`] for its lifetime, so tree builds stop
//!   allocating histogram buffers after the first tree.
//!
//! Transport is in-process (threads as workers, as in the paper's validity
//! experiments): an unbounded mpsc channel for pushes and an RwLock'd
//! `Arc` snapshot for pulls — publish is O(1) pointer swap, pulls never
//! block publishes for long.

pub mod messages;
pub mod server;
pub mod worker;

pub use messages::{TargetSnapshot, TreePush};
pub use server::{Board, ServerCore};
pub use worker::run_worker;
