//! The parameter server (Algorithm 3).
//!
//! * [`messages`] — the wire types: versioned target snapshots
//!   (`L'_random` + sampled support) flowing server → workers, tree pushes
//!   flowing workers → server.
//! * [`server`] — `ServerCore`, the server state machine: owns the forest
//!   `F(x)`, the prediction vector **F**, the gradient engine (AOT/PJRT),
//!   and the sampler; every accepted tree triggers update F → resample →
//!   produce target → publish. `Board` is the shared pull/push surface.
//! * [`shard`] — the fused row-sharded accept pipeline (`target=fused`,
//!   default): F-update, Bernoulli sampling (counter-based, keyed on
//!   `(seed, version, row)`), grad/hess and eval partials run as **one
//!   pass per row shard** across `score_threads` threads, bit-identical
//!   to the serial reference path for every shard count. The serial path
//!   (`target=serial`) keeps the separate sweeps, routed through the
//!   blocked SoA scoring engine (`forest/score.rs`). Either pipeline
//!   draws its threads from the server's [`crate::util::Executor`] —
//!   under `pool=persistent` (default) a server-lifetime
//!   [`crate::util::ScorePool`] of parked workers, so per-tree dispatch
//!   is a condvar wake rather than OS thread spawn/join (DESIGN.md §11).
//! * [`sharded`] — the sharded parameter server (`ps_shards=N`): server
//!   state row-partitioned across shards (each running its slice of the
//!   accept pass through the same `shard` kernel), features partitioned
//!   for histogram aggregation with only **touched** bins crossing shard
//!   boundaries ([`messages::SparseBins`]), and published snapshots
//!   composed from per-shard versions — no global lock, bit-identical to
//!   the single-shard path for every shard count. Shard ↔ shard
//!   communication sits behind [`sharded::ShardTransport`] so a
//!   multi-process PS swaps the transport, not the logic.
//! * [`faulty`] — a lossy [`ShardTransport`] wrapper driven by a
//!   deterministic [`crate::util::FaultPlan`]: per-site drop / duplicate /
//!   delay with send-side retry under bounded backoff and a delivery
//!   timeout, proving the aggregation's `(source, epoch)` at-most-once
//!   contract holds under failure (DESIGN.md §14).
//! * [`worker`] — the worker loop: pull latest target, build a tree on the
//!   sampled sub-dataset, push. Workers are mutually blind; only the
//!   pull/build/push order *within* one worker is serialised, exactly the
//!   paper's asynchrony model. Each worker owns a
//!   [`crate::tree::HistogramPool`] for its lifetime, so tree builds stop
//!   allocating histogram buffers after the first tree, and a
//!   worker-lifetime build [`crate::util::Executor`] (`build_threads` ×
//!   `pool`), so intra-tree fork-join sections dispatch onto parked
//!   threads instead of spawning per leaf (DESIGN.md §12); idle polls
//!   back off exponentially ([`crate::util::Backoff`]) instead of
//!   spinning.
//!
//! Transport is in-process (threads as workers, as in the paper's validity
//! experiments): an unbounded mpsc channel for pushes and an RwLock'd
//! `Arc` snapshot for pulls — publish is O(1) pointer swap, pulls never
//! block publishes for long.

pub mod faulty;
pub mod messages;
pub mod server;
pub mod shard;
pub mod sharded;
pub mod worker;

pub use faulty::FaultyTransport;
pub use messages::{HistShardMsg, SparseBins, TargetSnapshot, TreePush};
pub use server::{Board, ServerCore};
pub use shard::{fused_accept_pass, AcceptInputs, FusedResult, TargetMode};
pub use sharded::{
    aggregate_sharded, compose_version, sharded_accept_pass, FeaturePartition, LocalTransport,
    RowPartition, ShardTransport, ShardVersions,
};
pub use worker::{run_worker, run_worker_harnessed, WorkerHarness};
