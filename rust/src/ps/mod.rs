//! The parameter server (Algorithm 3).
//!
//! * [`messages`] — the wire types: versioned target snapshots
//!   (`L'_random` + sampled support) flowing server → workers, tree pushes
//!   flowing workers → server.
//! * [`server`] — `ServerCore`, the server state machine: owns the forest
//!   `F(x)`, the prediction vector **F**, the gradient engine (AOT/PJRT),
//!   and the sampler; every accepted tree triggers update F → resample →
//!   produce target → publish. `Board` is the shared pull/push surface.
//! * [`shard`] — the fused row-sharded accept pipeline (`target=fused`,
//!   default): F-update, Bernoulli sampling (counter-based, keyed on
//!   `(seed, version, row)`), grad/hess and eval partials run as **one
//!   pass per row shard** across `score_threads` threads, bit-identical
//!   to the serial reference path for every shard count. The serial path
//!   (`target=serial`) keeps the separate sweeps, routed through the
//!   blocked SoA scoring engine (`forest/score.rs`). Either pipeline
//!   draws its threads from the server's [`crate::util::Executor`] —
//!   under `pool=persistent` (default) a server-lifetime
//!   [`crate::util::ScorePool`] of parked workers, so per-tree dispatch
//!   is a condvar wake rather than OS thread spawn/join (DESIGN.md §11).
//! * [`worker`] — the worker loop: pull latest target, build a tree on the
//!   sampled sub-dataset, push. Workers are mutually blind; only the
//!   pull/build/push order *within* one worker is serialised, exactly the
//!   paper's asynchrony model. Each worker owns a
//!   [`crate::tree::HistogramPool`] for its lifetime, so tree builds stop
//!   allocating histogram buffers after the first tree, and a
//!   worker-lifetime build [`crate::util::Executor`] (`build_threads` ×
//!   `pool`), so intra-tree fork-join sections dispatch onto parked
//!   threads instead of spawning per leaf (DESIGN.md §12); idle polls
//!   back off exponentially ([`crate::util::Backoff`]) instead of
//!   spinning.
//!
//! Transport is in-process (threads as workers, as in the paper's validity
//! experiments): an unbounded mpsc channel for pushes and an RwLock'd
//! `Arc` snapshot for pulls — publish is O(1) pointer swap, pulls never
//! block publishes for long.

pub mod messages;
pub mod server;
pub mod shard;
pub mod worker;

pub use messages::{TargetSnapshot, TreePush};
pub use server::{Board, ServerCore};
pub use shard::{fused_accept_pass, AcceptInputs, FusedResult, TargetMode};
pub use worker::run_worker;
