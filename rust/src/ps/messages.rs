//! PS wire types.

use std::sync::Arc;

use crate::tree::Tree;

/// What workers pull: one version of the stochastic target `L'_random`
/// (Eq. 10) and the sampled sub-dataset it lives on.
///
/// `grad`/`hess` are full-length vectors indexed by global row id (zero
/// outside the support); `rows` is the sampled support, ascending. Arcs
/// make a pull an O(1) pointer clone — workers never copy the vectors.
#[derive(Debug, Clone)]
pub struct TargetSnapshot {
    /// Server version j: number of trees accepted when this was published.
    pub version: u64,
    /// Stochastic gradient target (full-length, zero off-support).
    pub grad: Arc<Vec<f32>>,
    /// Hessian target (weights in gradient mode; full-length).
    pub hess: Arc<Vec<f32>>,
    /// Sampled rows (support of m' > 0), ascending.
    pub rows: Arc<Vec<u32>>,
}

impl TargetSnapshot {
    /// An empty snapshot (used before the server publishes version 0).
    pub fn empty() -> TargetSnapshot {
        TargetSnapshot {
            version: 0,
            grad: Arc::new(Vec::new()),
            hess: Arc::new(Vec::new()),
            rows: Arc::new(Vec::new()),
        }
    }

    /// Size of the sampled support.
    pub fn n_sampled(&self) -> usize {
        self.rows.len()
    }
}

/// What workers push: a tree and the snapshot version it was built from
/// (`based_on` = k(j) in the paper; the server's accept counter at apply
/// time minus this is the realised delay τ).
#[derive(Debug, Clone)]
pub struct TreePush {
    /// Which worker built the tree.
    pub worker_id: usize,
    /// Target version the tree was built from (k(j)).
    pub based_on: u64,
    /// The freshly built tree.
    pub tree: Tree,
    /// Worker-side build time (profiling; calibrates the simulator).
    pub build_secs: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_snapshot() {
        let s = TargetSnapshot::empty();
        assert_eq!(s.version, 0);
        assert_eq!(s.n_sampled(), 0);
    }

    #[test]
    fn snapshot_pull_is_pointer_clone() {
        let s = TargetSnapshot {
            version: 3,
            grad: Arc::new(vec![1.0; 1000]),
            hess: Arc::new(vec![1.0; 1000]),
            rows: Arc::new((0..1000).collect()),
        };
        let t = s.clone();
        assert!(Arc::ptr_eq(&s.grad, &t.grad));
        assert_eq!(t.version, 3);
        assert_eq!(t.n_sampled(), 1000);
    }
}
