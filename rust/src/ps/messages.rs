//! PS wire types.
//!
//! Besides the snapshot/push pair the single-server path speaks, this
//! module carries the shard ↔ shard histogram exchange of the sharded
//! parameter server (`ps/sharded.rs`): a [`SparseBins`] payload encodes
//! only the **touched** bins of a slot range — Vasiloudis et al.'s
//! sparse-communication observation (PAPERS.md) — so shard traffic is
//! O(nnz) instead of O(total_bins), and [`HistShardMsg`] wraps one such
//! payload with its routing metadata.

use std::ops::Range;
use std::sync::Arc;

use crate::tree::histogram::{Histogram, LeafStats};
use crate::tree::Tree;

/// What workers pull: one version of the stochastic target `L'_random`
/// (Eq. 10) and the sampled sub-dataset it lives on.
///
/// `grad`/`hess` are full-length vectors indexed by global row id (zero
/// outside the support); `rows` is the sampled support, ascending. Arcs
/// make a pull an O(1) pointer clone — workers never copy the vectors.
#[derive(Debug, Clone)]
pub struct TargetSnapshot {
    /// Server version j: number of trees accepted when this was published.
    pub version: u64,
    /// Stochastic gradient target (full-length, zero off-support).
    pub grad: Arc<Vec<f32>>,
    /// Hessian target (weights in gradient mode; full-length).
    pub hess: Arc<Vec<f32>>,
    /// Sampled rows (support of m' > 0), ascending.
    pub rows: Arc<Vec<u32>>,
}

impl TargetSnapshot {
    /// An empty snapshot (used before the server publishes version 0).
    pub fn empty() -> TargetSnapshot {
        TargetSnapshot {
            version: 0,
            grad: Arc::new(Vec::new()),
            hess: Arc::new(Vec::new()),
            rows: Arc::new(Vec::new()),
        }
    }

    /// Size of the sampled support.
    pub fn n_sampled(&self) -> usize {
        self.rows.len()
    }
}

/// What workers push: a tree and the snapshot version it was built from
/// (`based_on` = k(j) in the paper; the server's accept counter at apply
/// time minus this is the realised delay τ).
#[derive(Debug, Clone)]
pub struct TreePush {
    /// Which worker built the tree.
    pub worker_id: usize,
    /// Target version the tree was built from (k(j)).
    pub based_on: u64,
    /// The freshly built tree.
    pub tree: Tree,
    /// Worker-side build time (profiling; calibrates the simulator).
    pub build_secs: f64,
}

/// Sparse encoding of one slot range of a flat [`Histogram`]: only the
/// touched (nonzero) slots cross a shard boundary, as parallel arrays
/// keyed by ascending global slot id.
///
/// The ascending order is load-bearing twice over: it makes the encoding
/// a pure function of the histogram's *contents* (the builder's
/// `touched` list is insertion-ordered, i.e. row-order dependent), and
/// it lets [`SparseBins::apply_to`] replay deterministically. Combined
/// with receivers merging messages in `from_shard` order, the assembled
/// histogram is bit-identical for any row sharding of the same rows —
/// each slot's f64 sum is grouped per source shard exactly as the dense
/// whole-matrix build groups it per row run.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SparseBins {
    /// Touched global slot ids, strictly ascending.
    pub slots: Vec<u32>,
    /// Gradient sum per listed slot.
    pub grad: Vec<f64>,
    /// Hessian sum per listed slot.
    pub hess: Vec<f64>,
    /// Row count per listed slot.
    pub count: Vec<u32>,
}

impl SparseBins {
    /// Encode the touched slots of `h` that fall in `slot_range`
    /// (a feature-partition's half-open global slot window), ascending.
    pub fn from_histogram(h: &Histogram, slot_range: Range<usize>) -> SparseBins {
        let mut slots: Vec<u32> = h
            .touched
            .iter()
            .copied()
            .filter(|&s| slot_range.contains(&(s as usize)))
            .collect();
        slots.sort_unstable();
        let mut out = SparseBins {
            grad: Vec::with_capacity(slots.len()),
            hess: Vec::with_capacity(slots.len()),
            count: Vec::with_capacity(slots.len()),
            slots,
        };
        for &s in &out.slots {
            let s = s as usize;
            out.grad.push(h.grad[s]);
            out.hess.push(h.hess[s]);
            out.count.push(h.count[s]);
        }
        out
    }

    /// Accumulate this payload into a flat histogram (the receiving
    /// shard's merge step), maintaining the untouched-slots-are-zero
    /// invariant. Slot totals are NOT folded here — the sender ships
    /// row totals once per message ([`HistShardMsg::totals`]), not per
    /// destination, so a row split across feature shards counts once.
    pub fn apply_to(&self, h: &mut Histogram) {
        for (i, &slot) in self.slots.iter().enumerate() {
            let s = slot as usize;
            if h.count[s] == 0 && self.count[i] > 0 {
                h.touched.push(slot);
            }
            h.grad[s] += self.grad[i];
            h.hess[s] += self.hess[i];
            h.count[s] += self.count[i];
        }
    }

    /// Number of encoded slots.
    pub fn n_slots(&self) -> usize {
        self.slots.len()
    }

    /// Bytes this payload would occupy on a wire (4-byte slot id +
    /// two f64 sums + 4-byte count per slot) — what the simulator's
    /// shard-exchange cost model charges per message.
    pub fn wire_bytes(&self) -> usize {
        self.slots.len() * 24
    }
}

/// One shard → shard histogram message of the sharded PS: the sender's
/// sparse contribution to the receiver's owned slot window, plus the
/// sender's row totals (shipped once per message so the receiver can
/// reassemble `Histogram::totals` without double counting).
#[derive(Debug, Clone)]
pub struct HistShardMsg {
    /// Sending shard id (receivers merge in ascending sender order —
    /// part of the bit-identity argument, see [`SparseBins`]).
    pub from_shard: usize,
    /// Receiving shard id (owner of every slot in `bins`).
    pub to_shard: usize,
    /// The sparse payload, restricted to the receiver's slot window.
    pub bins: SparseBins,
    /// Totals over the sender's rows (grad/hess/count sums).
    pub totals: LeafStats,
    /// Aggregation round this message belongs to. Receivers keep only
    /// the current round and at most one message per `(from_shard,
    /// epoch)` — the at-most-once contract that makes the exchange safe
    /// under retries, duplicates, and stale replays (DESIGN.md §14).
    pub epoch: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_snapshot() {
        let s = TargetSnapshot::empty();
        assert_eq!(s.version, 0);
        assert_eq!(s.n_sampled(), 0);
    }

    #[test]
    fn snapshot_pull_is_pointer_clone() {
        let s = TargetSnapshot {
            version: 3,
            grad: Arc::new(vec![1.0; 1000]),
            hess: Arc::new(vec![1.0; 1000]),
            rows: Arc::new((0..1000).collect()),
        };
        let t = s.clone();
        assert!(Arc::ptr_eq(&s.grad, &t.grad));
        assert_eq!(t.version, 3);
        assert_eq!(t.n_sampled(), 1000);
    }

    /// A hand-built 8-slot histogram with deliberately out-of-order
    /// `touched` (as `Histogram::build` produces: insertion order).
    fn scattered_hist() -> Histogram {
        let mut h = Histogram::zeros(8);
        for (slot, g, hs, c) in [(5u32, 2.0f64, 1.0f64, 2u32), (1, -3.0, 0.5, 1), (6, 4.0, 2.0, 3)] {
            let s = slot as usize;
            h.grad[s] = g;
            h.hess[s] = hs;
            h.count[s] = c;
            h.touched.push(slot);
            h.totals.grad += g;
            h.totals.hess += hs;
            h.totals.count += c as u64;
        }
        h
    }

    #[test]
    fn sparse_bins_encode_only_touched_slots_in_window_ascending() {
        let h = scattered_hist();
        let b = SparseBins::from_histogram(&h, 0..8);
        assert_eq!(b.slots, vec![1, 5, 6], "ascending regardless of touch order");
        assert_eq!(b.grad, vec![-3.0, 2.0, 4.0]);
        assert_eq!(b.count, vec![1, 2, 3]);
        assert_eq!(b.n_slots(), 3);
        assert_eq!(b.wire_bytes(), 3 * 24);
        // a narrower window drops slots outside it
        let lo = SparseBins::from_histogram(&h, 0..4);
        assert_eq!(lo.slots, vec![1]);
        let hi = SparseBins::from_histogram(&h, 4..8);
        assert_eq!(hi.slots, vec![5, 6]);
        assert_eq!(SparseBins::from_histogram(&h, 2..5).n_slots(), 0);
    }

    #[test]
    fn sparse_bins_apply_reassembles_the_source_bins() {
        let h = scattered_hist();
        // split the slot space into two windows, ship each, reassemble
        let mut back = Histogram::zeros(8);
        SparseBins::from_histogram(&h, 0..4).apply_to(&mut back);
        SparseBins::from_histogram(&h, 4..8).apply_to(&mut back);
        for s in 0..8 {
            assert_eq!(back.grad[s], h.grad[s], "slot {s}");
            assert_eq!(back.hess[s], h.hess[s], "slot {s}");
            assert_eq!(back.count[s], h.count[s], "slot {s}");
        }
        let mut got: Vec<u32> = back.touched.clone();
        let mut want: Vec<u32> = h.touched.clone();
        got.sort_unstable();
        want.sort_unstable();
        assert_eq!(got, want, "touched invariant after apply");
    }

    #[test]
    fn hist_shard_msg_carries_totals_once() {
        let h = scattered_hist();
        let msg = HistShardMsg {
            from_shard: 0,
            to_shard: 1,
            bins: SparseBins::from_histogram(&h, 4..8),
            totals: h.totals,
            epoch: 0,
        };
        // totals describe the sender's rows, not the shipped window:
        // count 6 even though the window holds only slots 5 and 6
        assert_eq!(msg.totals.count, 6);
        assert_eq!(msg.bins.n_slots(), 2);
    }
}
