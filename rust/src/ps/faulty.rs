//! A lossy [`ShardTransport`] wrapper driven by a deterministic
//! [`FaultPlan`] (DESIGN.md §14).
//!
//! [`FaultyTransport`] sits between `aggregate_sharded` and any inner
//! transport and injects, per send, whatever the plan decided for the
//! `(shard_send, from → to, attempt)` key:
//!
//! * **Drop** — the message is discarded and the sender retries under a
//!   bounded [`Backoff`], consuming fresh attempt numbers, until a
//!   non-drop decision or the [`MAX_SEND_ATTEMPTS`] delivery timeout
//!   forces it through (liveness is unconditional).
//! * **Duplicate** — the live copy is delivered twice in the current
//!   round (exercising the receiver's same-epoch dedup) and a third,
//!   stale copy is parked until a later drain (exercising the
//!   cross-epoch filter).
//! * **Delay** — the message is delivered after the plan's bounded
//!   injected latency.
//!
//! The attempt counter per `(from, to)` pair is the only shared state a
//! send touches besides the inner transport, so concurrent senders to
//! different pairs never contend — and because every decision is a pure
//! function of its key, the set of faults a run experiences depends only
//! on which attempt numbers get exercised, not on thread scheduling.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::util::fault::{FaultAction, FaultPlan, FaultSite};
use crate::util::Backoff;

use super::messages::HistShardMsg;
use super::sharded::ShardTransport;

/// Delivery timeout: after this many consecutive injected drops of one
/// message the wrapper delivers it anyway. Keeps chaos runs live at any
/// drop rate (even 1.0) while still exercising the retry loop — forced
/// deliveries are counted so tests can see when the timeout fired.
pub const MAX_SEND_ATTEMPTS: u64 = 16;

/// The fault-injecting transport wrapper. See the module docs for the
/// per-action semantics; `drain` releases parked stale replays (aged by
/// one per drain) before forwarding to the inner transport.
pub struct FaultyTransport<'a> {
    inner: &'a dyn ShardTransport,
    plan: &'a FaultPlan,
    max_shards: usize,
    /// Per-(from, to) attempt counters, `from * max_shards + to`.
    attempts: Vec<AtomicU64>,
    /// Stale replays parked per destination: (drains to wait, message).
    parked: Vec<Mutex<Vec<(u8, HistShardMsg)>>>,
    forced: AtomicU64,
}

impl<'a> FaultyTransport<'a> {
    /// Wrap `inner`, injecting `plan`'s shard-send faults. `max_shards`
    /// must exceed every `from_shard`/`to_shard` this transport will see
    /// (use the larger of the row- and feature-shard counts).
    pub fn new(
        inner: &'a dyn ShardTransport,
        plan: &'a FaultPlan,
        max_shards: usize,
    ) -> FaultyTransport<'a> {
        let m = max_shards.max(1);
        FaultyTransport {
            inner,
            plan,
            max_shards: m,
            attempts: (0..m * m).map(|_| AtomicU64::new(0)).collect(),
            parked: (0..m).map(|_| Mutex::new(Vec::new())).collect(),
            forced: AtomicU64::new(0),
        }
    }

    /// How many messages the delivery timeout forced through after
    /// [`MAX_SEND_ATTEMPTS`] consecutive drops.
    pub fn forced_deliveries(&self) -> u64 {
        self.forced.load(Ordering::Relaxed)
    }
}

impl ShardTransport for FaultyTransport<'_> {
    fn send(&self, msg: HistShardMsg) {
        assert!(
            msg.from_shard < self.max_shards && msg.to_shard < self.max_shards,
            "shard id out of range for this FaultyTransport"
        );
        let site = FaultSite::shard_send(msg.from_shard, msg.to_shard);
        let pair = msg.from_shard * self.max_shards + msg.to_shard;
        let mut backoff = Backoff::new();
        let mut drops = 0u64;
        loop {
            let attempt = self.attempts[pair].fetch_add(1, Ordering::Relaxed);
            match self.plan.apply(site, attempt) {
                FaultAction::Drop => {
                    drops += 1;
                    if drops >= MAX_SEND_ATTEMPTS {
                        // delivery timeout: stop retrying, force through
                        self.forced.fetch_add(1, Ordering::Relaxed);
                        self.inner.send(msg);
                        return;
                    }
                    backoff.idle();
                }
                FaultAction::Duplicate => {
                    // two live copies now + one stale replay parked for a
                    // future round's drain
                    self.inner.send(msg.clone());
                    self.inner.send(msg.clone());
                    self.parked[msg.to_shard].lock().unwrap().push((1, msg));
                    return;
                }
                FaultAction::Delay => {
                    std::thread::sleep(self.plan.delay_for(site, attempt));
                    self.inner.send(msg);
                    return;
                }
                // Panic never occurs on shard-send sites (see FaultPlan::
                // decide) — treat it as a clean delivery for exhaustiveness
                FaultAction::Deliver | FaultAction::Panic => {
                    self.inner.send(msg);
                    return;
                }
            }
        }
    }

    fn drain(&self, shard: usize) -> Vec<HistShardMsg> {
        // release parked replays whose wait expired; age the rest
        let mut out = Vec::new();
        {
            let mut q = self.parked[shard].lock().unwrap();
            let mut still = Vec::with_capacity(q.len());
            for (wait, m) in q.drain(..) {
                if wait == 0 {
                    out.push(m);
                } else {
                    still.push((wait - 1, m));
                }
            }
            *q = still;
        }
        out.extend(self.inner.drain(shard));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ps::sharded::LocalTransport;
    use crate::tree::histogram::LeafStats;
    use crate::util::fault::FaultSpec;

    fn msg(from: usize, to: usize, epoch: u64) -> HistShardMsg {
        HistShardMsg {
            from_shard: from,
            to_shard: to,
            bins: Default::default(),
            totals: LeafStats::default(),
            epoch,
        }
    }

    #[test]
    fn every_send_is_delivered_even_at_drop_rate_one() {
        let inner = LocalTransport::new(2);
        let plan = FaultPlan::new(
            1,
            FaultSpec {
                drop_rate: 1.0,
                ..FaultSpec::default()
            },
        );
        let t = FaultyTransport::new(&inner, &plan, 2);
        for i in 0..3u64 {
            t.send(msg(0, 1, i));
        }
        assert_eq!(t.drain(1).len(), 3, "liveness despite 100% drops");
        assert_eq!(t.forced_deliveries(), 3, "every delivery was forced");
        let c = plan.counts();
        assert_eq!(c.drops, 3 * MAX_SEND_ATTEMPTS);
    }

    #[test]
    fn duplicates_deliver_twice_now_and_park_a_stale_replay() {
        let inner = LocalTransport::new(2);
        let plan = FaultPlan::new(
            2,
            FaultSpec {
                dup_rate: 1.0,
                ..FaultSpec::default()
            },
        );
        let t = FaultyTransport::new(&inner, &plan, 2);
        t.send(msg(0, 1, 7));
        assert_eq!(t.drain(1).len(), 2, "two live copies this round");
        assert_eq!(t.drain(1).len(), 1, "stale replay released next round");
        assert!(t.drain(1).is_empty());
        assert_eq!(plan.counts().dups, 1);
    }

    #[test]
    fn clean_plan_is_a_passthrough() {
        let inner = LocalTransport::new(2);
        let plan = FaultPlan::new(3, FaultSpec::default());
        let t = FaultyTransport::new(&inner, &plan, 2);
        t.send(msg(1, 0, 0));
        t.send(msg(0, 0, 0));
        assert_eq!(t.drain(0).len(), 2);
        assert!(plan.trace().is_empty());
        assert_eq!(t.forced_deliveries(), 0);
    }
}
