//! Worker side of the PS (Algorithm 3 "For Worker").
//!
//! Pull → build → push, forever, blind to other workers. The only
//! synchronisation a worker ever touches is the O(1) snapshot pull and the
//! non-blocking channel send — there is no barrier anywhere, which is the
//! paper's entire point.
//!
//! Each worker owns one [`crate::tree::HistogramPool`] for its whole
//! lifetime: the flat histogram buffers are allocated on the first tree
//! and recycled across every node of every subsequent tree (see the pool's
//! ownership contract), so the steady-state build loop is allocation-free
//! on its hot path.
//!
//! Each worker also receives a worker-lifetime
//! [`crate::util::Executor`] for *intra-tree* parallelism: every tree is
//! built through [`crate::tree::build_tree_feature_parallel`], whose
//! per-leaf sharded histogram builds and work-stealing split searches
//! dispatch onto the executor. With the default `build_threads=1` the
//! executor is a free pass-through and the build is exactly the serial
//! learner; with `build_threads>1` under `pool=persistent` one pool of
//! parked threads serves every fork-join cycle of every tree the worker
//! ever builds — the worker-side removal of the per-histogram spawn/join
//! cost the paper's §II attributes to fork-join GBDT (DESIGN.md §12).
//!
//! Workers are oblivious to `ps_shards`: the sharded PS
//! (`ps/sharded.rs`) changes how the *server* produces a snapshot (its
//! version becomes a composition of per-shard versions), but the board
//! still hands workers one immutable `TargetSnapshot` — the pull → build
//! → push loop is byte-for-byte the same at every shard count.

use std::sync::mpsc::Sender;
use std::sync::Arc;

use crate::data::BinnedDataset;
use crate::tree::{build_tree_feature_parallel, HistogramPool, TreeParams};
use crate::util::fault::{FaultAction, FaultPlan, FaultSite};
use crate::util::{Backoff, Executor, Rng, Stopwatch};

use super::messages::TreePush;
use super::server::Board;

/// Fault/supervision context for one worker *incarnation* — what the
/// supervised async trainer wires in, and what the default loop runs
/// without. The default harness (`WorkerHarness::default()`) arms
/// nothing: no plan, no heartbeats, incarnation 0 — the loop body is
/// then byte-identical to the pre-supervision worker (two always-false
/// branches on stack data; no atomics, DESIGN.md §14).
#[derive(Debug, Clone, Copy, Default)]
pub struct WorkerHarness<'a> {
    /// Which life of the worker this is (0 = first spawn; each
    /// supervisor restart increments it and derives a fresh RNG
    /// identity via [`crate::util::fault::worker_identity_seed`]).
    pub incarnation: u64,
    /// Armed fault plan: injected panics at
    /// `(worker_panic, wid, incarnation)` sites and push faults at
    /// `(worker_push, wid, incarnation)` sites, keyed by build cycle.
    pub faults: Option<&'a FaultPlan>,
    /// Bump the board's per-worker heartbeat each cycle so the
    /// supervisor can observe liveness.
    pub heartbeat: bool,
}

/// Run one worker loop until the board signals shutdown or the push
/// channel closes. `exec` is the worker-lifetime build executor (see the
/// module docs). Returns the number of trees pushed. Equivalent to
/// [`run_worker_harnessed`] with the default (unarmed) harness.
pub fn run_worker(
    worker_id: usize,
    board: &Board,
    binned: Arc<BinnedDataset>,
    params: TreeParams,
    exec: &Executor,
    tx: Sender<TreePush>,
    seed: u64,
) -> usize {
    run_worker_harnessed(
        worker_id,
        board,
        binned,
        params,
        exec,
        tx,
        seed,
        &WorkerHarness::default(),
    )
}

/// [`run_worker`] with a supervision/fault harness: the same
/// pull → build → push loop, plus (when armed) a heartbeat per cycle, a
/// deterministic injected panic check before each build, and
/// deterministic drop/duplicate/delay faults on each push. Every fault
/// decision is keyed on `(site, build_cycle)` where the cycle counter
/// advances only on successful pulls — so the schedule of faults is a
/// pure function of the plan, not of timing.
#[allow(clippy::too_many_arguments)]
pub fn run_worker_harnessed(
    worker_id: usize,
    board: &Board,
    binned: Arc<BinnedDataset>,
    params: TreeParams,
    exec: &Executor,
    tx: Sender<TreePush>,
    seed: u64,
    harness: &WorkerHarness<'_>,
) -> usize {
    let mut rng = Rng::new(seed ^ (worker_id as u64).wrapping_mul(0xA24B_AED4_963E_E407));
    let mut pushed = 0usize;
    // one pool per worker, held across trees: allocate once, recycle forever
    let mut pool = HistogramPool::new(binned.total_bins());
    // bounded exponential backoff while the server has nothing published:
    // a raw yield-spin burns a core (and steals cycles from the server
    // producing version 0); parked sleeps cap the cost, reset on success
    let mut backoff = Backoff::new();
    // build-cycle counter: the per-incarnation attempt index every fault
    // decision below is keyed on (empty polls don't advance it)
    let mut cycle = 0u64;
    while !board.is_shutdown() {
        if harness.heartbeat {
            board.beat(worker_id);
        }
        // 1. pull the current L'_random
        let snapshot = board.pull();
        if snapshot.grad.is_empty() {
            // server not initialised yet; back off and retry
            backoff.idle();
            continue;
        }
        backoff.reset();
        let this_cycle = cycle;
        cycle += 1;
        // injected crash: a pure function of (fault_seed, worker,
        // incarnation, cycle), so a chaos run's death schedule is
        // replayable from the plan alone
        if let Some(plan) = harness.faults {
            let site = FaultSite::worker_panic(worker_id, harness.incarnation);
            if plan.apply(site, this_cycle) == FaultAction::Panic {
                panic!(
                    "injected fault: worker {worker_id} incarnation {} panics at build cycle {this_cycle}",
                    harness.incarnation
                );
            }
        }
        // 2. build Tree_t on the sampled sub-dataset (pooled buffers,
        //    executor-backed intra-tree parallelism)
        let mut sw = Stopwatch::new();
        let tree = build_tree_feature_parallel(
            &binned,
            &snapshot.rows,
            &snapshot.grad,
            &snapshot.hess,
            &params,
            &mut rng,
            exec,
            &mut pool,
        );
        let build_secs = sw.lap();
        // 3. send Tree_t to server — possibly faulted
        let push = TreePush {
            worker_id,
            based_on: snapshot.version,
            tree,
            build_secs,
        };
        let push_site = FaultSite::worker_push(worker_id, harness.incarnation);
        let action = match harness.faults {
            Some(plan) => plan.apply(push_site, this_cycle),
            None => FaultAction::Deliver,
        };
        match action {
            FaultAction::Drop => {
                // the tree is lost in flight; build the next one
                continue;
            }
            FaultAction::Duplicate => {
                // the server sees the same tree twice (the second copy is
                // stale on arrival and stresses the accept path)
                if tx.send(push.clone()).is_err() || tx.send(push).is_err() {
                    break; // server hung up
                }
            }
            FaultAction::Delay => {
                let plan = harness.faults.expect("delay decided without a plan");
                std::thread::sleep(plan.delay_for(push_site, this_cycle));
                if tx.send(push).is_err() {
                    break; // server hung up
                }
            }
            FaultAction::Deliver | FaultAction::Panic => {
                if tx.send(push).is_err() {
                    break; // server hung up
                }
            }
        }
        pushed += 1;
    }
    pushed
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{synthetic, Dataset};
    use crate::testkit;
    use std::sync::mpsc;

    fn board_with_target(ds: &Dataset, binned: &BinnedDataset) -> Board {
        let board = Board::new();
        let fx = testkit::logistic_fixture(ds, 16);
        board.publish(crate::ps::TargetSnapshot {
            version: 0,
            grad: Arc::new(fx.grad),
            hess: Arc::new(vec![1.0f32; ds.n_rows()]),
            rows: Arc::new(fx.rows),
        });
        let _ = binned;
        board
    }

    #[test]
    fn worker_pushes_until_shutdown() {
        let ds = synthetic::realsim_like(150, 1);
        let binned = Arc::new(BinnedDataset::from_dataset(&ds, 16).unwrap());
        let board = board_with_target(&ds, &binned);
        let (tx, rx) = mpsc::channel();
        let params = TreeParams {
            max_leaves: 4,
            ..Default::default()
        };
        std::thread::scope(|s| {
            let board_ref = &board;
            let b = binned.clone();
            let h = s.spawn(move || {
                let exec = Executor::scoped(1);
                run_worker(3, board_ref, b, params, &exec, tx, 7)
            });
            // collect a few pushes then stop
            let mut got = Vec::new();
            for _ in 0..3 {
                got.push(rx.recv().unwrap());
            }
            board.request_shutdown();
            // drain until the worker exits
            while let Ok(p) = rx.recv() {
                got.push(p);
            }
            let pushed = h.join().unwrap();
            assert!(pushed >= 3);
            assert_eq!(pushed, got.len());
            for p in &got {
                assert_eq!(p.worker_id, 3);
                assert_eq!(p.based_on, 0);
                assert!(p.tree.n_leaves() >= 1);
                assert!(p.build_secs >= 0.0);
            }
        });
    }

    #[test]
    fn worker_backs_off_on_empty_board_then_picks_up_first_target() {
        // the board starts unpublished: the worker must park (not wedge)
        // and still catch version 0 promptly once it appears
        let ds = synthetic::realsim_like(120, 3);
        let binned = Arc::new(BinnedDataset::from_dataset(&ds, 16).unwrap());
        let board = Board::new();
        let (tx, rx) = mpsc::channel();
        std::thread::scope(|s| {
            let board_ref = &board;
            let b = binned.clone();
            let h = s.spawn(move || {
                let params = TreeParams {
                    max_leaves: 4,
                    ..Default::default()
                };
                let exec = Executor::scoped(1);
                run_worker(1, board_ref, b, params, &exec, tx, 11)
            });
            // let the worker reach the deep end of its backoff schedule
            std::thread::sleep(std::time::Duration::from_millis(20));
            let late = board_with_target(&ds, &binned);
            board.publish(late.pull().as_ref().clone());
            let first = rx.recv().unwrap();
            assert_eq!(first.based_on, 0);
            board.request_shutdown();
            while rx.try_recv().is_ok() {}
            assert!(h.join().unwrap() >= 1);
        });
    }

    #[test]
    fn worker_exits_when_channel_closes() {
        let ds = synthetic::realsim_like(100, 2);
        let binned = Arc::new(BinnedDataset::from_dataset(&ds, 16).unwrap());
        let board = board_with_target(&ds, &binned);
        let (tx, rx) = mpsc::channel();
        std::thread::scope(|s| {
            let board_ref = &board;
            let b = binned.clone();
            let h = s.spawn(move || {
                let params = TreeParams {
                    max_leaves: 2,
                    ..Default::default()
                };
                let exec = Executor::scoped(1);
                run_worker(0, board_ref, b, params, &exec, tx, 1)
            });
            let _first = rx.recv().unwrap();
            drop(rx); // hang up
            let pushed = h.join().unwrap();
            assert!(pushed >= 1);
        });
    }

    #[test]
    fn worker_with_parallel_build_executor_pushes_valid_trees() {
        // the worker-lifetime persistent executor path: intra-tree builds
        // dispatch onto one pool across every pushed tree
        let ds = synthetic::realsim_like(200, 5);
        let binned = Arc::new(BinnedDataset::from_dataset(&ds, 16).unwrap());
        let board = board_with_target(&ds, &binned);
        let (tx, rx) = mpsc::channel();
        std::thread::scope(|s| {
            let board_ref = &board;
            let b = binned.clone();
            let h = s.spawn(move || {
                let params = TreeParams {
                    max_leaves: 8,
                    ..Default::default()
                };
                let exec = Executor::new(crate::util::PoolMode::Persistent, 2);
                run_worker(2, board_ref, b, params, &exec, tx, 23)
            });
            let mut got = Vec::new();
            for _ in 0..5 {
                got.push(rx.recv().unwrap());
            }
            board.request_shutdown();
            while rx.try_recv().is_ok() {}
            assert!(h.join().unwrap() >= 5);
            for p in &got {
                p.tree.validate().unwrap();
            }
        });
    }

    #[test]
    fn harnessed_worker_beats_heartbeats_and_panics_on_schedule() {
        use crate::util::fault::{FaultPlan, FaultSpec};

        let ds = synthetic::realsim_like(120, 4);
        let binned = Arc::new(BinnedDataset::from_dataset(&ds, 16).unwrap());
        let board = Board::with_heartbeats(1);
        board.publish(board_with_target(&ds, &binned).pull().as_ref().clone());
        // panic_rate 1.0: incarnation 0 must die at build cycle 0, before
        // pushing anything — the deterministic crash the supervisor catches
        let plan = FaultPlan::new(
            13,
            FaultSpec {
                panic_rate: 1.0,
                ..FaultSpec::default()
            },
        );
        let (tx, rx) = mpsc::channel();
        std::thread::scope(|s| {
            let board_ref = &board;
            let b = binned.clone();
            let plan_ref = &plan;
            let h = s.spawn(move || {
                let params = TreeParams {
                    max_leaves: 4,
                    ..Default::default()
                };
                let exec = Executor::scoped(1);
                let harness = WorkerHarness {
                    incarnation: 0,
                    faults: Some(plan_ref),
                    heartbeat: true,
                };
                std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    run_worker_harnessed(0, board_ref, b, params, &exec, tx, 5, &harness)
                }))
            });
            let outcome = h.join().unwrap();
            let payload = outcome.expect_err("rigged worker must panic");
            let msg = payload.downcast_ref::<String>().unwrap();
            assert!(msg.contains("worker 0"), "panic names the worker: {msg}");
            assert!(msg.contains("cycle 0"), "panic names the cycle: {msg}");
            assert!(rx.try_recv().is_err(), "died before any push");
            assert!(board.heartbeat(0) >= 1, "beat at least once before dying");
            let trace = plan.trace();
            assert_eq!(trace.len(), 1, "exactly the injected panic recorded");
            assert_eq!(trace[0].action, crate::util::FaultAction::Panic);
        });
    }
}
