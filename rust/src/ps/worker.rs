//! Worker side of the PS (Algorithm 3 "For Worker").
//!
//! Pull → build → push, forever, blind to other workers. The only
//! synchronisation a worker ever touches is the O(1) snapshot pull and the
//! non-blocking channel send — there is no barrier anywhere, which is the
//! paper's entire point.
//!
//! Each worker owns one [`crate::tree::HistogramPool`] for its whole
//! lifetime: the flat histogram buffers are allocated on the first tree
//! and recycled across every node of every subsequent tree (see the pool's
//! ownership contract), so the steady-state build loop is allocation-free
//! on its hot path.
//!
//! Each worker also receives a worker-lifetime
//! [`crate::util::Executor`] for *intra-tree* parallelism: every tree is
//! built through [`crate::tree::build_tree_feature_parallel`], whose
//! per-leaf sharded histogram builds and work-stealing split searches
//! dispatch onto the executor. With the default `build_threads=1` the
//! executor is a free pass-through and the build is exactly the serial
//! learner; with `build_threads>1` under `pool=persistent` one pool of
//! parked threads serves every fork-join cycle of every tree the worker
//! ever builds — the worker-side removal of the per-histogram spawn/join
//! cost the paper's §II attributes to fork-join GBDT (DESIGN.md §12).
//!
//! Workers are oblivious to `ps_shards`: the sharded PS
//! (`ps/sharded.rs`) changes how the *server* produces a snapshot (its
//! version becomes a composition of per-shard versions), but the board
//! still hands workers one immutable `TargetSnapshot` — the pull → build
//! → push loop is byte-for-byte the same at every shard count.

use std::sync::mpsc::Sender;
use std::sync::Arc;

use crate::data::BinnedDataset;
use crate::tree::{build_tree_feature_parallel, HistogramPool, TreeParams};
use crate::util::{Backoff, Executor, Rng, Stopwatch};

use super::messages::TreePush;
use super::server::Board;

/// Run one worker loop until the board signals shutdown or the push
/// channel closes. `exec` is the worker-lifetime build executor (see the
/// module docs). Returns the number of trees pushed.
pub fn run_worker(
    worker_id: usize,
    board: &Board,
    binned: Arc<BinnedDataset>,
    params: TreeParams,
    exec: &Executor,
    tx: Sender<TreePush>,
    seed: u64,
) -> usize {
    let mut rng = Rng::new(seed ^ (worker_id as u64).wrapping_mul(0xA24B_AED4_963E_E407));
    let mut pushed = 0usize;
    // one pool per worker, held across trees: allocate once, recycle forever
    let mut pool = HistogramPool::new(binned.total_bins());
    // bounded exponential backoff while the server has nothing published:
    // a raw yield-spin burns a core (and steals cycles from the server
    // producing version 0); parked sleeps cap the cost, reset on success
    let mut backoff = Backoff::new();
    while !board.is_shutdown() {
        // 1. pull the current L'_random
        let snapshot = board.pull();
        if snapshot.grad.is_empty() {
            // server not initialised yet; back off and retry
            backoff.idle();
            continue;
        }
        backoff.reset();
        // 2. build Tree_t on the sampled sub-dataset (pooled buffers,
        //    executor-backed intra-tree parallelism)
        let mut sw = Stopwatch::new();
        let tree = build_tree_feature_parallel(
            &binned,
            &snapshot.rows,
            &snapshot.grad,
            &snapshot.hess,
            &params,
            &mut rng,
            exec,
            &mut pool,
        );
        let build_secs = sw.lap();
        // 3. send Tree_t to server
        let push = TreePush {
            worker_id,
            based_on: snapshot.version,
            tree,
            build_secs,
        };
        if tx.send(push).is_err() {
            break; // server hung up
        }
        pushed += 1;
    }
    pushed
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{synthetic, Dataset};
    use crate::testkit;
    use std::sync::mpsc;

    fn board_with_target(ds: &Dataset, binned: &BinnedDataset) -> Board {
        let board = Board::new();
        let fx = testkit::logistic_fixture(ds, 16);
        board.publish(crate::ps::TargetSnapshot {
            version: 0,
            grad: Arc::new(fx.grad),
            hess: Arc::new(vec![1.0f32; ds.n_rows()]),
            rows: Arc::new(fx.rows),
        });
        let _ = binned;
        board
    }

    #[test]
    fn worker_pushes_until_shutdown() {
        let ds = synthetic::realsim_like(150, 1);
        let binned = Arc::new(BinnedDataset::from_dataset(&ds, 16).unwrap());
        let board = board_with_target(&ds, &binned);
        let (tx, rx) = mpsc::channel();
        let params = TreeParams {
            max_leaves: 4,
            ..Default::default()
        };
        std::thread::scope(|s| {
            let board_ref = &board;
            let b = binned.clone();
            let h = s.spawn(move || {
                let exec = Executor::scoped(1);
                run_worker(3, board_ref, b, params, &exec, tx, 7)
            });
            // collect a few pushes then stop
            let mut got = Vec::new();
            for _ in 0..3 {
                got.push(rx.recv().unwrap());
            }
            board.request_shutdown();
            // drain until the worker exits
            while let Ok(p) = rx.recv() {
                got.push(p);
            }
            let pushed = h.join().unwrap();
            assert!(pushed >= 3);
            assert_eq!(pushed, got.len());
            for p in &got {
                assert_eq!(p.worker_id, 3);
                assert_eq!(p.based_on, 0);
                assert!(p.tree.n_leaves() >= 1);
                assert!(p.build_secs >= 0.0);
            }
        });
    }

    #[test]
    fn worker_backs_off_on_empty_board_then_picks_up_first_target() {
        // the board starts unpublished: the worker must park (not wedge)
        // and still catch version 0 promptly once it appears
        let ds = synthetic::realsim_like(120, 3);
        let binned = Arc::new(BinnedDataset::from_dataset(&ds, 16).unwrap());
        let board = Board::new();
        let (tx, rx) = mpsc::channel();
        std::thread::scope(|s| {
            let board_ref = &board;
            let b = binned.clone();
            let h = s.spawn(move || {
                let params = TreeParams {
                    max_leaves: 4,
                    ..Default::default()
                };
                let exec = Executor::scoped(1);
                run_worker(1, board_ref, b, params, &exec, tx, 11)
            });
            // let the worker reach the deep end of its backoff schedule
            std::thread::sleep(std::time::Duration::from_millis(20));
            let late = board_with_target(&ds, &binned);
            board.publish(late.pull().as_ref().clone());
            let first = rx.recv().unwrap();
            assert_eq!(first.based_on, 0);
            board.request_shutdown();
            while rx.try_recv().is_ok() {}
            assert!(h.join().unwrap() >= 1);
        });
    }

    #[test]
    fn worker_exits_when_channel_closes() {
        let ds = synthetic::realsim_like(100, 2);
        let binned = Arc::new(BinnedDataset::from_dataset(&ds, 16).unwrap());
        let board = board_with_target(&ds, &binned);
        let (tx, rx) = mpsc::channel();
        std::thread::scope(|s| {
            let board_ref = &board;
            let b = binned.clone();
            let h = s.spawn(move || {
                let params = TreeParams {
                    max_leaves: 2,
                    ..Default::default()
                };
                let exec = Executor::scoped(1);
                run_worker(0, board_ref, b, params, &exec, tx, 1)
            });
            let _first = rx.recv().unwrap();
            drop(rx); // hang up
            let pushed = h.join().unwrap();
            assert!(pushed >= 1);
        });
    }

    #[test]
    fn worker_with_parallel_build_executor_pushes_valid_trees() {
        // the worker-lifetime persistent executor path: intra-tree builds
        // dispatch onto one pool across every pushed tree
        let ds = synthetic::realsim_like(200, 5);
        let binned = Arc::new(BinnedDataset::from_dataset(&ds, 16).unwrap());
        let board = board_with_target(&ds, &binned);
        let (tx, rx) = mpsc::channel();
        std::thread::scope(|s| {
            let board_ref = &board;
            let b = binned.clone();
            let h = s.spawn(move || {
                let params = TreeParams {
                    max_leaves: 8,
                    ..Default::default()
                };
                let exec = Executor::new(crate::util::PoolMode::Persistent, 2);
                run_worker(2, board_ref, b, params, &exec, tx, 23)
            });
            let mut got = Vec::new();
            for _ in 0..5 {
                got.push(rx.recv().unwrap());
            }
            board.request_shutdown();
            while rx.try_recv().is_ok() {}
            assert!(h.join().unwrap() >= 5);
            for p in &got {
                p.tree.validate().unwrap();
            }
        });
    }
}
