//! Training telemetry: loss curves (the paper's Figures 5–9 raw data),
//! staleness statistics (the observed delay τ distribution), and worker
//! supervision outcomes (deaths/restarts under fault injection).

use std::path::Path;

use anyhow::Result;

use crate::io::csv::CsvWriter;
use crate::util::stats::Summary;

/// One evaluation point along training.
#[derive(Debug, Clone, Copy)]
pub struct CurvePoint {
    /// Number of trees in the forest when evaluated.
    pub n_trees: usize,
    /// Full-train-set mean logloss.
    pub train_loss: f64,
    /// Held-out mean logloss (NaN if no test set).
    pub test_loss: f64,
    /// Held-out error rate (NaN if no test set).
    pub test_error: f64,
    /// Wall-clock seconds since training start.
    pub wall_secs: f64,
}

/// A recorded loss curve.
#[derive(Debug, Clone, Default)]
pub struct LossCurve {
    /// Points in recording order (ascending n_trees).
    pub points: Vec<CurvePoint>,
}

impl LossCurve {
    /// Append a point.
    pub fn push(&mut self, p: CurvePoint) {
        self.points.push(p);
    }

    /// Train loss of the last recorded point.
    pub fn final_train_loss(&self) -> Option<f64> {
        self.points.last().map(|p| p.train_loss)
    }

    /// Test loss of the last recorded point.
    pub fn final_test_loss(&self) -> Option<f64> {
        self.points.last().map(|p| p.test_loss)
    }

    /// Smallest n_trees at which train loss drops to `target` or below
    /// (the "epochs to reach ε" statistic used in convergence comparisons).
    pub fn trees_to_reach(&self, target: f64) -> Option<usize> {
        self.points
            .iter()
            .find(|p| p.train_loss <= target)
            .map(|p| p.n_trees)
    }

    /// Area under the (n_trees, train_loss) curve via trapezoids — a
    /// scalar convergence-speed summary used by the sensitivity benches.
    pub fn train_loss_auc(&self) -> f64 {
        let pts = &self.points;
        if pts.len() < 2 {
            return pts.first().map(|p| p.train_loss).unwrap_or(0.0);
        }
        let mut area = 0.0;
        for w in pts.windows(2) {
            let dx = (w[1].n_trees - w[0].n_trees) as f64;
            area += dx * (w[0].train_loss + w[1].train_loss) / 2.0;
        }
        let span = (pts.last().unwrap().n_trees - pts[0].n_trees) as f64;
        if span > 0.0 {
            area / span
        } else {
            pts[0].train_loss
        }
    }

    /// Write as CSV (columns match the paper figures' axes).
    pub fn write_csv(&self, path: &Path, tag: &str) -> Result<()> {
        let mut w = CsvWriter::new(&[
            "tag",
            "n_trees",
            "train_loss",
            "test_loss",
            "test_error",
            "wall_secs",
        ]);
        for p in &self.points {
            w.row(&[
                tag.to_string(),
                p.n_trees.to_string(),
                format!("{:.6}", p.train_loss),
                format!("{:.6}", p.test_loss),
                format!("{:.6}", p.test_error),
                format!("{:.4}", p.wall_secs),
            ]);
        }
        w.write(path)
    }
}

/// Observed staleness (τ = server_version_at_apply − version_pulled)
/// histogram over accepted pushes.
#[derive(Debug, Clone, Default)]
pub struct StalenessStats {
    /// τ of every accepted push, in acceptance order.
    pub samples: Vec<u64>,
    /// Pushes rejected by the bounded-staleness filter.
    pub rejected: u64,
}

impl StalenessStats {
    /// Record one accepted push's τ.
    pub fn record(&mut self, tau: u64) {
        self.samples.push(tau);
    }

    /// Count one rejected push.
    pub fn record_rejected(&mut self) {
        self.rejected += 1;
    }

    /// Distribution summary of the accepted τ samples.
    pub fn summary(&self) -> Summary {
        Summary::of(&self.samples.iter().map(|&s| s as f64).collect::<Vec<_>>())
    }

    /// Largest accepted τ (0 if none).
    pub fn max(&self) -> u64 {
        self.samples.iter().copied().max().unwrap_or(0)
    }

    /// Mean accepted τ (0 if none).
    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            0.0
        } else {
            self.samples.iter().sum::<u64>() as f64 / self.samples.len() as f64
        }
    }
}

/// Effective step lengths actually applied to accepted pushes. Under
/// `step=fixed` every sample equals `step_length`; under
/// `step=adaptive` each sample is `StepMode::effective(v, τ)` for that
/// push's recorded τ, so the trace doubles as a replayable record of
/// the adaptive rule (DESIGN.md §17).
#[derive(Debug, Clone, Default)]
pub struct StepStats {
    /// Effective v of every accepted push, in acceptance order.
    pub samples: Vec<f32>,
}

impl StepStats {
    /// Record one accepted push's effective step length.
    pub fn record(&mut self, v_eff: f32) {
        self.samples.push(v_eff);
    }

    /// Mean effective step length (0 if none recorded).
    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            0.0
        } else {
            self.samples.iter().map(|&v| v as f64).sum::<f64>() / self.samples.len() as f64
        }
    }

    /// Smallest effective step length applied (0 if none recorded).
    pub fn min(&self) -> f32 {
        if self.samples.is_empty() {
            0.0
        } else {
            self.samples.iter().copied().fold(f32::INFINITY, f32::min)
        }
    }
}

/// Worker supervision outcome of one training run: how many workers the
/// run was configured with, how many lives were lost to (injected or
/// real) panics, how many restarts the supervisor granted, and how many
/// workers were still alive at shutdown. Invariant:
/// `deaths - restarts == workers - workers_final` (every death is either
/// restarted or retires its worker).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SupervisionStats {
    /// Workers the run was configured with.
    pub workers: usize,
    /// Worker deaths observed (each panic of an incarnation is one).
    pub deaths: u64,
    /// Restarts the supervisor granted (each consumed one death).
    pub restarts: u64,
    /// Workers still alive when the run shut down.
    pub workers_final: usize,
}

impl SupervisionStats {
    /// Stats for a run with no supervision events: every worker lives.
    pub fn all_alive(workers: usize) -> SupervisionStats {
        SupervisionStats {
            workers,
            deaths: 0,
            restarts: 0,
            workers_final: workers,
        }
    }

    /// Workers that permanently died (restart budget exhausted).
    pub fn workers_lost(&self) -> usize {
        self.workers.saturating_sub(self.workers_final)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn curve(points: &[(usize, f64)]) -> LossCurve {
        LossCurve {
            points: points
                .iter()
                .map(|&(n, l)| CurvePoint {
                    n_trees: n,
                    train_loss: l,
                    test_loss: l,
                    test_error: 0.1,
                    wall_secs: n as f64 * 0.1,
                })
                .collect(),
        }
    }

    #[test]
    fn trees_to_reach_finds_first_crossing() {
        let c = curve(&[(0, 0.7), (10, 0.5), (20, 0.4), (30, 0.35)]);
        assert_eq!(c.trees_to_reach(0.5), Some(10));
        assert_eq!(c.trees_to_reach(0.42), Some(20));
        assert_eq!(c.trees_to_reach(0.1), None);
    }

    #[test]
    fn auc_averages_loss() {
        let c = curve(&[(0, 1.0), (10, 0.0)]);
        assert!((c.train_loss_auc() - 0.5).abs() < 1e-12);
        let flat = curve(&[(0, 0.3), (10, 0.3), (20, 0.3)]);
        assert!((flat.train_loss_auc() - 0.3).abs() < 1e-12);
    }

    #[test]
    fn auc_degenerate_cases() {
        assert_eq!(LossCurve::default().train_loss_auc(), 0.0);
        let single = curve(&[(5, 0.42)]);
        assert!((single.train_loss_auc() - 0.42).abs() < 1e-12);
    }

    #[test]
    fn csv_written_with_tag() {
        let c = curve(&[(0, 0.7), (10, 0.6)]);
        let path = std::env::temp_dir().join("asgbdt_curve_test.csv");
        c.write_csv(&path, "w4_r0.8").unwrap();
        let body = std::fs::read_to_string(&path).unwrap();
        assert!(body.starts_with("tag,n_trees,train_loss"));
        assert!(body.contains("w4_r0.8,10,0.600000"));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn staleness_stats() {
        let mut s = StalenessStats::default();
        for tau in [0u64, 1, 2, 3, 10] {
            s.record(tau);
        }
        s.record_rejected();
        assert_eq!(s.max(), 10);
        assert!((s.mean() - 3.2).abs() < 1e-12);
        assert_eq!(s.rejected, 1);
        assert_eq!(s.summary().n, 5);
    }

    #[test]
    fn step_stats_trace_mean_and_min() {
        let mut s = StepStats::default();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.min(), 0.0);
        for v in [0.3f32, 0.15, 0.1] {
            s.record(v);
        }
        assert_eq!(s.samples, vec![0.3, 0.15, 0.1]);
        assert!((s.mean() - (0.3f32 as f64 + 0.15f32 as f64 + 0.1f32 as f64) / 3.0).abs() < 1e-12);
        assert_eq!(s.min(), 0.1);
    }

    #[test]
    fn supervision_stats_invariant_and_defaults() {
        let quiet = SupervisionStats::all_alive(4);
        assert_eq!(quiet.workers_final, 4);
        assert_eq!(quiet.workers_lost(), 0);
        let churned = SupervisionStats {
            workers: 4,
            deaths: 5,
            restarts: 3,
            workers_final: 2,
        };
        // deaths - restarts == workers - workers_final
        assert_eq!(
            churned.deaths - churned.restarts,
            (churned.workers - churned.workers_final) as u64
        );
        assert_eq!(churned.workers_lost(), 2);
    }
}
